//! Open-loop load generation: drive any [`ServingBackend`] at a target
//! Poisson arrival rate, independent of completions.
//!
//! Trace replay ([`crate::server::replay_backend`]) measures a system
//! against a *pre-generated* arrival schedule; an open-loop generator is
//! the online complement — arrivals are drawn on the fly from an
//! exponential inter-arrival distribution and injected at their wall
//! clock instants whether or not the backend keeps up. That property
//! (arrivals never wait for service) is what exposes deadline misses and
//! queue growth under overload, which closed-loop clients hide.
//!
//! [`drive`] works against *any* [`ServingBackend`]: a single
//! [`Engine`], an in-process fleet [`Coordinator`], or a remote NDJSON
//! server through [`NdjsonClient`]. [`run_fleet_open_loop`] /
//! [`sweep_fleet_policies`] wrap the in-process fleet case for the
//! routing-policy comparison (`expertweave loadgen`, `cargo bench
//! --bench fig12_fleet_online` → `BENCH_fleet_online.json`).
//!
//! [`Engine`]: crate::engine::Engine
//! [`Coordinator`]: crate::coordinator::Coordinator
//! [`NdjsonClient`]: crate::serving::frontend::NdjsonClient

use crate::adapters::generator::synth_fleet_adapters;
use crate::coordinator::{Coordinator, CoordinatorConfig, FleetStats, RoutingPolicy};
use crate::engine::{Engine, EngineOptions};
use crate::metrics::Report;
use crate::model::ModelConfig;
use crate::obs::trace::TraceLog;
use crate::runtime::{SimPerf, Variant};
use crate::sampler::SamplingParams;
use crate::serving::{
    AbortReason, RequestHandle, ServeRequest, ServingBackend, SubmitError, TokenEvent,
};
use crate::util::json::{arr, obj, Json};
use crate::util::rng::Pcg;
use crate::util::stats::{Samples, Summary};
use crate::weights::StoreMode;
use crate::workload::power_law::power_law_shares;
use anyhow::{bail, Result};
use std::time::{Duration, Instant};

/// One open-loop serving session: who arrives, how often, for how long.
#[derive(Debug, Clone)]
pub struct OpenLoopSpec {
    /// Aggregate arrival rate (requests/second, Poisson).
    pub rate: f64,
    /// Arrival horizon in seconds (in-flight work is still drained
    /// afterwards; the outcome's `wall` covers the whole session).
    pub horizon: f64,
    /// Adapter names to address, weighted by `alpha`; empty = every
    /// request targets the base model.
    pub adapters: Vec<String>,
    /// Power-law skew across `adapters` (1 = uniform; smaller = more
    /// skew), as in [`power_law_shares`].
    pub alpha: f64,
    /// Mean prompt length (token count varies ±50% around it).
    pub prompt_len: usize,
    /// Output budget per request.
    pub max_new: usize,
    /// Relative completion deadline attached to every request.
    pub deadline: Option<Duration>,
    /// Vocabulary bound for the synthetic prompt tokens.
    pub vocab: usize,
    /// Fraction (`0..=1`) of every prompt drawn from its adapter's
    /// shared preamble pool instead of fresh random tokens — the
    /// ESFT-style "identical task preamble" pattern that the paged KV
    /// cache's prefix sharing exploits. Preambles are deterministic per
    /// (adapter, pool slot), so two requests hitting the same slot carry
    /// byte-identical prefixes across replicas and runs.
    pub prefix_overlap: f64,
    /// Fraction (`0..=1`) of requests issued as *sampled* decodes
    /// (temperature + nucleus filter with a per-request seed drawn from
    /// the workload stream) instead of greedy — exercises the mixed
    /// greedy+sampled batch path under load. `0.0` keeps the legacy
    /// all-greedy mix and leaves the arrival stream byte-identical to
    /// pre-v5 runs.
    pub sampled_frac: f64,
    pub seed: u64,
}

/// Distinct preambles per adapter in the shared-prefix pool: overlap
/// concentrates on a handful of "system prompts" per task, not one.
pub const PREAMBLE_POOL: u64 = 4;

/// Deterministic preamble token for `(adapter slot, pool slot, position)`
/// — stateless, so every generator (openloop, loadgen, fig13) agrees on
/// the shared prefixes without coordinating.
pub fn preamble_token(adapter_ix: u64, pool: u64, pos: usize, vocab: usize) -> i32 {
    let mut x = adapter_ix
        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
        .wrapping_add(pool.wrapping_mul(0xbf58_476d_1ce4_e5b9))
        .wrapping_add((pos as u64).wrapping_mul(0x94d0_49bb_1331_11eb))
        .wrapping_add(0x5eed);
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^= x >> 31;
    (1 + x % (vocab.saturating_sub(1).max(1) as u64)) as i32
}

impl Default for OpenLoopSpec {
    fn default() -> Self {
        OpenLoopSpec {
            rate: 20.0,
            horizon: 2.0,
            adapters: Vec::new(),
            alpha: 0.5,
            prompt_len: 16,
            max_new: 8,
            deadline: None,
            vocab: 512,
            prefix_overlap: 0.0,
            sampled_frac: 0.0,
            seed: 0,
        }
    }
}

/// What happened to an open-loop session's offered load.
#[derive(Debug, Clone)]
pub struct OpenLoopOutcome {
    /// Arrivals generated (== completed + rejected + misses + aborts).
    pub offered: usize,
    pub completed: usize,
    /// Typed submit rejections other than deadline admission
    /// (queue-full, shed, unknown adapter, ...), plus post-routing
    /// rejections surfaced as [`AbortReason::Rejected`].
    pub rejected: usize,
    /// Refused at the door because no backend/replica could meet the
    /// deadline ([`SubmitError::DeadlineUnmeetable`], at submit or after
    /// routing).
    pub deadline_unmeetable: usize,
    /// Admitted but expired before completing
    /// ([`AbortReason::DeadlineExceeded`]).
    pub deadline_expired: usize,
    /// Admitted, then the serving replica died and the remaining
    /// deadline could not survive a re-route
    /// ([`AbortReason::ReplicaLost`]). Requests that *were* re-routed
    /// successfully show up under `completed` like any other.
    pub replica_lost: usize,
    /// Other admitted-but-not-completed requests (cancellations).
    pub aborted_other: usize,
    /// TTFT over completed requests (seconds).
    pub ttft: Summary,
    /// End-to-end latency over completed requests (seconds).
    pub e2e: Summary,
    /// Session wall time in seconds (arrival horizon + drain tail).
    pub wall: f64,
}

impl OpenLoopOutcome {
    /// Fraction of offered requests that missed their deadline — either
    /// refused at the door as unmeetable or expired in flight. `NaN`
    /// when nothing was offered.
    pub fn deadline_miss_rate(&self) -> f64 {
        if self.offered == 0 {
            return f64::NAN;
        }
        (self.deadline_unmeetable + self.deadline_expired) as f64 / self.offered as f64
    }

    /// One fixed-width summary row for CLI/bench output.
    pub fn row(&self, label: &str) -> String {
        format!(
            "{label:<18} offered={:<5} done={:<5} ttft p50={:>7.1} ms p99={:>7.1} ms \
             miss={:>5.1}% (door={} expired={}) rej={} wall={:.1}s",
            self.offered,
            self.completed,
            self.ttft.median * 1e3,
            self.ttft.p99 * 1e3,
            self.deadline_miss_rate() * 100.0,
            self.deadline_unmeetable,
            self.deadline_expired,
            self.rejected,
            self.wall,
        )
        + &if self.replica_lost > 0 {
            format!(" lost={}", self.replica_lost)
        } else {
            String::new()
        }
    }
}

/// Draw one synthetic request.
fn gen_request(rng: &mut Pcg, spec: &OpenLoopSpec, shares: &[f64]) -> ServeRequest {
    let (adapter, adapter_ix) = if spec.adapters.is_empty() {
        (None, u64::MAX) // base model draws from its own preamble pool
    } else {
        let i = rng.categorical(shares);
        (Some(spec.adapters[i].clone()), i as u64)
    };
    let base = spec.prompt_len.max(2);
    let len = (base / 2 + rng.below(base as u64) as usize).max(1);
    // the leading `overlap` fraction comes from one of the adapter's
    // shared preambles; the tail stays request-private random tokens
    let overlap = spec.prefix_overlap.clamp(0.0, 1.0);
    let shared = ((len as f64) * overlap).round() as usize;
    let pool = rng.below(PREAMBLE_POOL);
    let prompt = (0..len)
        .map(|p| {
            if p < shared {
                preamble_token(adapter_ix, pool, p, spec.vocab)
            } else {
                (1 + rng.below(spec.vocab.saturating_sub(1).max(1) as u64)) as i32
            }
        })
        .collect();
    // the extra draws happen only when the sampled mix is enabled, so a
    // sampled_frac of 0 reproduces the pre-v5 request stream exactly
    let sampling = if spec.sampled_frac > 0.0 && rng.f64() < spec.sampled_frac.min(1.0) {
        SamplingParams::top_p(0.9, 0.8).with_seed(rng.next_u64())
    } else {
        SamplingParams::greedy()
    };
    ServeRequest {
        adapter,
        prompt,
        max_new_tokens: spec.max_new.max(1),
        sampling,
        deadline: spec.deadline,
        trace: None,
    }
}

/// Drive `backend` open-loop: inject Poisson arrivals on the wall clock
/// for `spec.horizon` seconds (arrivals never wait for completions),
/// then pump until every admitted request reached a terminal event.
pub fn drive<B: ServingBackend>(backend: &mut B, spec: &OpenLoopSpec) -> Result<OpenLoopOutcome> {
    if spec.rate <= 0.0 || !spec.rate.is_finite() {
        bail!("open-loop rate must be positive and finite (got {})", spec.rate);
    }
    let shares = if spec.adapters.is_empty() {
        Vec::new()
    } else {
        power_law_shares(spec.adapters.len(), spec.alpha)
    };
    let mut rng = Pcg::with_stream(spec.seed, 9191);
    let mut outcome = OpenLoopOutcome {
        offered: 0,
        completed: 0,
        rejected: 0,
        deadline_unmeetable: 0,
        deadline_expired: 0,
        replica_lost: 0,
        aborted_other: 0,
        ttft: Samples::new().summary(),
        e2e: Samples::new().summary(),
        wall: 0.0,
    };
    let mut ttft = Samples::new();
    let mut e2e = Samples::new();
    let mut handles: Vec<RequestHandle> = Vec::new();

    let start = Instant::now();
    let mut next_at = rng.exp(spec.rate);
    // liveness bound for the drain tail: a healthy backend terminates
    // every admitted request; if one stream never closes, fail loudly
    // instead of spinning forever
    let stall_limit = Duration::from_secs_f64(spec.horizon + 120.0);

    loop {
        let now = start.elapsed().as_secs_f64();
        while next_at <= now && next_at <= spec.horizon {
            let req = gen_request(&mut rng, spec, &shares);
            outcome.offered += 1;
            match backend.submit(req) {
                Ok(h) => handles.push(h),
                Err(SubmitError::DeadlineUnmeetable) => outcome.deadline_unmeetable += 1,
                Err(_) => outcome.rejected += 1,
            }
            next_at += rng.exp(spec.rate);
        }
        if backend.has_work() {
            backend.pump()?;
            sweep(&mut handles, &mut outcome, &mut ttft, &mut e2e);
        } else if next_at <= spec.horizon {
            // idle before the next arrival: sleep the remaining wait
            let wait = next_at - start.elapsed().as_secs_f64();
            if wait > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(wait));
            }
        } else if handles.is_empty() {
            break;
        } else {
            // arrivals are done and the backend reports idle, but some
            // streams have not delivered their terminal event yet
            // (threaded backends deliver asynchronously)
            backend.pump()?;
            sweep(&mut handles, &mut outcome, &mut ttft, &mut e2e);
        }
        if start.elapsed() > stall_limit {
            bail!(
                "open-loop drive stalled: {} stream(s) never terminated",
                handles.len()
            );
        }
    }
    sweep(&mut handles, &mut outcome, &mut ttft, &mut e2e);
    outcome.ttft = ttft.summary();
    outcome.e2e = e2e.summary();
    outcome.wall = start.elapsed().as_secs_f64();
    Ok(outcome)
}

/// Drain every live stream into the outcome counters; drop finished
/// handles.
fn sweep(
    handles: &mut Vec<RequestHandle>,
    outcome: &mut OpenLoopOutcome,
    ttft: &mut Samples,
    e2e: &mut Samples,
) {
    handles.retain(|h| {
        let mut terminal = false;
        for ev in h.drain_events() {
            match ev {
                TokenEvent::Done { completion, .. } => {
                    terminal = true;
                    outcome.completed += 1;
                    ttft.push(completion.record.ttft.as_secs_f64());
                    e2e.push(completion.record.e2e.as_secs_f64());
                }
                TokenEvent::Aborted { reason, .. } => {
                    terminal = true;
                    match reason {
                        AbortReason::DeadlineExceeded => outcome.deadline_expired += 1,
                        AbortReason::Rejected(SubmitError::DeadlineUnmeetable) => {
                            outcome.deadline_unmeetable += 1
                        }
                        AbortReason::Rejected(_) => outcome.rejected += 1,
                        AbortReason::ReplicaLost => outcome.replica_lost += 1,
                        AbortReason::Cancelled => outcome.aborted_other += 1,
                    }
                }
                TokenEvent::First { .. } | TokenEvent::Token { .. } => {}
            }
        }
        !terminal
    });
}

/// In-process fleet setup for the policy comparison: `replicas` sim
/// engines behind a [`Coordinator`], `n_adapters` synthetic ESFT
/// adapters host-cached, driven open-loop.
#[derive(Debug, Clone)]
pub struct FleetLoadSpec {
    pub replicas: usize,
    pub n_adapters: usize,
    /// Resident-adapter budget per replica.
    pub adapter_capacity: usize,
    /// Per-adapter outstanding cap (0 = unbounded).
    pub queue_cap: usize,
    /// Hardware model of every replica.
    pub perf: SimPerf,
    /// Chunked-prefill budget per sequence per step.
    pub chunk: usize,
    /// Concurrent-sequence cap per replica (keeps the sim near
    /// saturation so routing quality is visible).
    pub max_seqs: usize,
    /// The arrival process (its `adapters`/`vocab` fields are filled
    /// from the synthesized fleet).
    pub open_loop: OpenLoopSpec,
}

impl FleetLoadSpec {
    /// The policy-comparison hardware model: each replica completes
    /// ~25 req/s under the default request shape (prompt ~24 / max_new
    /// 8 / max_seqs 4), so the default two-replica fleet runs near
    /// saturation against ~50 req/s offered — placement quality, not
    /// spare capacity, decides who meets deadlines. Shared by
    /// `expertweave loadgen` and `benches/fig12_fleet_online.rs` so the
    /// two stay calibrated together.
    pub fn near_saturation_perf() -> SimPerf {
        SimPerf {
            step_base: Duration::from_millis(15),
            per_token: Duration::from_micros(200),
            adapter_swap: Duration::from_millis(25),
        }
    }
}

impl Default for FleetLoadSpec {
    fn default() -> Self {
        FleetLoadSpec {
            replicas: 2,
            n_adapters: 4,
            adapter_capacity: 3,
            queue_cap: 0,
            perf: Self::near_saturation_perf(),
            chunk: 64,
            max_seqs: 4,
            open_loop: OpenLoopSpec::default(),
        }
    }
}

/// Mean per-phase dwell times across completed requests, derived from
/// the merged fleet trace's phase spans — where e2e latency was spent
/// (waiting in a queue, prefilling, or decoding), not just how long it
/// was.
#[derive(Debug, Clone, Default)]
pub struct PhaseBreakdown {
    /// Completed spans with a full queued→prefill→decode timeline.
    pub requests: usize,
    /// Admission (or arrival) → first scheduled, mean ms.
    pub queue_ms: f64,
    /// First scheduled → prefill done, mean ms.
    pub prefill_ms: f64,
    /// Prefill done → finished, mean ms.
    pub decode_ms: f64,
}

impl PhaseBreakdown {
    /// One-line summary for loadgen output.
    pub fn row(&self) -> String {
        format!(
            "phases ({} reqs): queue {:.2}ms | prefill {:.2}ms | decode {:.2}ms",
            self.requests, self.queue_ms, self.prefill_ms, self.decode_ms
        )
    }
}

/// Compute the [`PhaseBreakdown`] of every completed request span in a
/// (merged fleet) trace.
pub fn phase_breakdown(trace: &TraceLog) -> PhaseBreakdown {
    let mut queue = Samples::new();
    let mut prefill = Samples::new();
    let mut decode = Samples::new();
    for s in trace.spans() {
        if s.outcome != "done" {
            continue;
        }
        let (Some(sched), Some(pfd)) = (s.first_scheduled_us, s.prefill_done_us) else {
            continue;
        };
        let start = s.admitted_us.unwrap_or(s.arrival_us);
        queue.push(sched.saturating_sub(start) as f64 / 1e3);
        prefill.push(pfd.saturating_sub(sched) as f64 / 1e3);
        decode.push(s.finished_us.saturating_sub(pfd) as f64 / 1e3);
    }
    if queue.is_empty() {
        return PhaseBreakdown::default();
    }
    PhaseBreakdown {
        requests: queue.len(),
        queue_ms: queue.mean(),
        prefill_ms: prefill.mean(),
        decode_ms: decode.mean(),
    }
}

/// One policy's result in a [`sweep_fleet_policies`] comparison.
#[derive(Debug)]
pub struct PolicyOutcome {
    pub policy: RoutingPolicy,
    pub outcome: OpenLoopOutcome,
    pub stats: FleetStats,
    pub per_replica: Vec<Report>,
    /// Where completed requests spent their time, from the merged fleet
    /// trace (zeros when no request completed).
    pub phases: PhaseBreakdown,
}

/// Launch a sim fleet with `policy`, drive it open-loop per `spec`,
/// drain, and join the replica threads.
pub fn run_fleet_open_loop(spec: &FleetLoadSpec, policy: RoutingPolicy) -> Result<PolicyOutcome> {
    let mut cfg = ModelConfig::sim_default();
    cfg.max_adapters = spec.adapter_capacity.max(1);
    let adapters = synth_fleet_adapters(&cfg, spec.n_adapters, 42);
    let mut ol = spec.open_loop.clone();
    ol.adapters = adapters.iter().map(|a| a.name.clone()).collect();
    ol.vocab = cfg.vocab;

    let coord_cfg = CoordinatorConfig {
        replicas: spec.replicas,
        policy,
        adapter_capacity: spec.adapter_capacity.max(1),
        queue_cap: spec.queue_cap,
        replicate_rps: f64::INFINITY,
        rate_halflife: 2.0,
        max_copies: spec.replicas.min(2).max(1),
        ..Default::default()
    };
    let spawn_cfg = cfg.clone();
    let perf = spec.perf;
    let chunk = spec.chunk;
    let max_seqs = spec.max_seqs;
    let mut coord = Coordinator::launch(
        coord_cfg,
        move |i| {
            let cfg = spawn_cfg.clone();
            let opts = EngineOptions {
                chunk,
                max_seqs,
                page_size: 64 << 10,
                seed: i as u64,
                ..Default::default()
            };
            Box::new(move || {
                Engine::sim_weave(&cfg, perf, &[], Variant::Weave, StoreMode::Virtual, opts)
            })
        },
        adapters,
    )?;
    coord.enable_trace()?;
    let started = Instant::now();
    let outcome = drive(&mut coord, &ol)?;
    ServingBackend::drain(&mut coord)?;
    let (per_replica, stats, trace) = coord.finish_traced(started)?;
    let phases = trace.as_ref().map(phase_breakdown).unwrap_or_default();
    Ok(PolicyOutcome { policy, outcome, stats, per_replica, phases })
}

/// Run [`run_fleet_open_loop`] once per policy with identical arrival
/// processes (same spec/seed), for the Fig. 12 comparison.
pub fn sweep_fleet_policies(
    spec: &FleetLoadSpec,
    policies: &[RoutingPolicy],
) -> Result<Vec<PolicyOutcome>> {
    policies
        .iter()
        .map(|&p| run_fleet_open_loop(spec, p))
        .collect()
}

/// Render a sweep as the `BENCH_fleet_online.json` document.
pub fn fleet_online_json(spec: &FleetLoadSpec, rows: &[PolicyOutcome]) -> Json {
    let policies = rows
        .iter()
        .map(|r| {
            obj(vec![
                ("policy", Json::Str(r.policy.as_str().into())),
                ("offered", Json::Int(r.outcome.offered as i64)),
                ("completed", Json::Int(r.outcome.completed as i64)),
                ("rejected", Json::Int(r.outcome.rejected as i64)),
                (
                    "deadline_unmeetable",
                    Json::Int(r.outcome.deadline_unmeetable as i64),
                ),
                (
                    "deadline_expired",
                    Json::Int(r.outcome.deadline_expired as i64),
                ),
                (
                    "deadline_miss_rate",
                    Json::Num(r.outcome.deadline_miss_rate()),
                ),
                ("replica_lost", Json::Int(r.outcome.replica_lost as i64)),
                ("ttft_p50_ms", Json::Num(r.outcome.ttft.median * 1e3)),
                ("ttft_p99_ms", Json::Num(r.outcome.ttft.p99 * 1e3)),
                ("e2e_p50_ms", Json::Num(r.outcome.e2e.median * 1e3)),
                ("wall_s", Json::Num(r.outcome.wall)),
                ("affinity_hits", Json::Int(r.stats.affinity_hits as i64)),
                ("loads", Json::Int(r.stats.loads as i64)),
                ("shed", Json::Int(r.stats.shed_total() as i64)),
                ("phase_queue_ms", Json::Num(r.phases.queue_ms)),
                ("phase_prefill_ms", Json::Num(r.phases.prefill_ms)),
                ("phase_decode_ms", Json::Num(r.phases.decode_ms)),
            ])
        })
        .collect::<Vec<_>>();
    obj(vec![
        ("bench", Json::Str("fleet_online".into())),
        ("replicas", Json::Int(spec.replicas as i64)),
        ("adapters", Json::Int(spec.n_adapters as i64)),
        ("adapter_capacity", Json::Int(spec.adapter_capacity as i64)),
        ("rate_rps", Json::Num(spec.open_loop.rate)),
        ("horizon_s", Json::Num(spec.open_loop.horizon)),
        (
            "deadline_ms",
            spec.open_loop
                .deadline
                .map(|d| Json::Num(d.as_secs_f64() * 1e3))
                .unwrap_or(Json::Null),
        ),
        ("alpha", Json::Num(spec.open_loop.alpha)),
        ("prefix_overlap", Json::Num(spec.open_loop.prefix_overlap)),
        ("sampled_frac", Json::Num(spec.open_loop.sampled_frac)),
        ("seed", Json::Int(spec.open_loop.seed as i64)),
        ("policies", arr(policies)),
    ])
}
