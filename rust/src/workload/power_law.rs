//! Power-law request shares across adapters (the S-LoRA / paper skew
//! model): smaller `alpha` = heavier skew; `alpha = 1` = uniform.
//!
//! Share of adapter `i` (1-based rank) is proportional to
//! `rank^-(1 - alpha)` normalized over `n` adapters, matching the paper's
//! usage where alpha = 0.32 sends ~80% of traffic to the top adapter of
//! two and lower alpha pushes it to ~95%.

/// Normalized request shares for `n` adapters at skew `alpha` in (0, 1].
pub fn power_law_shares(n: usize, alpha: f64) -> Vec<f64> {
    assert!(n > 0);
    assert!(alpha > 0.0 && alpha <= 1.0);
    if n == 1 {
        return vec![1.0];
    }
    // exponent chosen so alpha=1 is uniform and alpha->0 concentrates
    // on rank 1. s = (1 - alpha) / alpha spans [0, inf).
    let s = (1.0 - alpha) / alpha;
    let raw: Vec<f64> = (1..=n).map(|r| (r as f64).powf(-s)).collect();
    let total: f64 = raw.iter().sum();
    raw.into_iter().map(|x| x / total).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_at_alpha_one() {
        let s = power_law_shares(5, 1.0);
        for v in &s {
            assert!((v - 0.2).abs() < 1e-12);
        }
    }

    #[test]
    fn shares_sum_to_one_and_are_monotone() {
        for &alpha in &[0.1, 0.3, 0.32, 0.7, 1.0] {
            for &n in &[1usize, 2, 5, 10, 20] {
                let s = power_law_shares(n, alpha);
                assert!((s.iter().sum::<f64>() - 1.0).abs() < 1e-9);
                assert!(s.windows(2).all(|w| w[0] >= w[1] - 1e-12));
            }
        }
    }

    #[test]
    fn lower_alpha_is_more_skewed() {
        let a = power_law_shares(10, 0.9);
        let b = power_law_shares(10, 0.2);
        assert!(b[0] > a[0]);
        assert!(b[9] < a[9]);
    }

    #[test]
    fn paper_two_adapter_calibration() {
        // paper: alpha = 0.32 -> ~80% to the top adapter of two;
        // lowering alpha -> up to 95%
        let s = power_law_shares(2, 0.32);
        assert!((s[0] - 0.80).abs() < 0.03, "top share {}", s[0]);
        let s = power_law_shares(2, 0.19);
        assert!(s[0] > 0.93, "top share {}", s[0]);
    }
}
