//! Synthetic domain prompts + a deterministic toy tokenizer.
//!
//! The paper samples prompts from each adapter's evaluation dataset
//! (GSM8K, intent, law, ...) and sends them only to adapters of that
//! domain. What the serving system observes is (a) the token-length
//! distribution and (b) the adapter affinity; we reproduce both with
//! per-domain length models calibrated to the datasets' rough shapes.

use crate::util::rng::Pcg;

/// Per-domain prompt/output length model (tokens).
#[derive(Debug, Clone, Copy)]
pub struct DomainShape {
    pub name: &'static str,
    pub prompt_mean: f64,
    pub prompt_std: f64,
    pub prompt_max: usize,
    pub out_mean: f64,
    pub out_max: usize,
}

/// Length models for the paper's five domains, scaled to the CPU testbed
/// (prompt budget <= 512-token bucket; see EXPERIMENTS.md "testbed scale").
pub const DOMAINS: [DomainShape; 5] = [
    DomainShape { name: "math", prompt_mean: 96.0, prompt_std: 32.0, prompt_max: 384, out_mean: 48.0, out_max: 96 },
    DomainShape { name: "intent", prompt_mean: 48.0, prompt_std: 16.0, prompt_max: 192, out_mean: 12.0, out_max: 24 },
    DomainShape { name: "summary", prompt_mean: 224.0, prompt_std: 64.0, prompt_max: 448, out_mean: 40.0, out_max: 80 },
    DomainShape { name: "law", prompt_mean: 160.0, prompt_std: 48.0, prompt_max: 416, out_mean: 56.0, out_max: 96 },
    DomainShape { name: "translation", prompt_mean: 80.0, prompt_std: 24.0, prompt_max: 320, out_mean: 64.0, out_max: 112 },
];

pub fn domain_shape(name: &str) -> &'static DomainShape {
    DOMAINS
        .iter()
        .find(|d| d.name == name)
        .unwrap_or(&DOMAINS[0])
}

/// Deterministic prompt generator over a model vocabulary.
#[derive(Debug)]
pub struct PromptGen {
    vocab: usize,
    rng: Pcg,
}

impl PromptGen {
    pub fn new(vocab: usize, seed: u64) -> Self {
        PromptGen { vocab, rng: Pcg::with_stream(seed, 42) }
    }

    /// Sample `(prompt_tokens, max_new_tokens)` for a domain.
    pub fn sample(&mut self, domain: &str) -> (Vec<i32>, usize) {
        let d = domain_shape(domain);
        let plen = self.trunc_normal(d.prompt_mean, d.prompt_std, 4, d.prompt_max);
        let olen = self.trunc_normal(d.out_mean, d.out_mean * 0.4, 1, d.out_max);
        // domain-flavoured token stream: each domain draws from its own
        // band of the vocabulary plus common tokens, mimicking topical
        // vocabulary concentration
        let band = fx(domain) as usize % 7;
        let band_lo = (self.vocab / 8) * (band % 8);
        let band_w = (self.vocab / 8).max(1);
        let toks = (0..plen)
            .map(|_| {
                if self.rng.below(3) == 0 {
                    // common tokens (ids 0..vocab/8)
                    (self.rng.below((self.vocab / 8).max(2) as u64)) as i32
                } else {
                    (band_lo as u64 + self.rng.below(band_w as u64)) as i32
                }
            })
            .collect();
        (toks, olen)
    }

    fn trunc_normal(&mut self, mean: f64, std: f64, lo: usize, hi: usize) -> usize {
        let x = mean + std * self.rng.normal();
        (x.round().max(lo as f64) as usize).min(hi)
    }
}

fn fx(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lengths_within_bounds() {
        let mut g = PromptGen::new(8192, 1);
        for d in DOMAINS {
            for _ in 0..200 {
                let (p, o) = g.sample(d.name);
                assert!(p.len() >= 4 && p.len() <= d.prompt_max);
                assert!(o >= 1 && o <= d.out_max);
                assert!(p.iter().all(|&t| (t as usize) < 8192 && t >= 0));
            }
        }
    }

    #[test]
    fn domain_means_roughly_hit() {
        let mut g = PromptGen::new(8192, 2);
        let d = domain_shape("summary");
        let n = 400;
        let mean: f64 = (0..n).map(|_| g.sample("summary").0.len() as f64).sum::<f64>() / n as f64;
        assert!((mean - d.prompt_mean).abs() < d.prompt_std, "mean {mean}");
    }

    #[test]
    fn deterministic() {
        let mut a = PromptGen::new(128, 7);
        let mut b = PromptGen::new(128, 7);
        assert_eq!(a.sample("law"), b.sample("law"));
    }

    #[test]
    fn unknown_domain_falls_back() {
        let mut g = PromptGen::new(128, 3);
        let (p, _) = g.sample("nope");
        assert!(!p.is_empty());
    }
}
