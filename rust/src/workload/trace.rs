//! Arrival traces: per-adapter Poisson processes whose rates follow the
//! power-law share split, executed concurrently over a horizon
//! (paper section 5.2 workload construction).

use super::power_law::power_law_shares;
use super::prompts::PromptGen;
use crate::util::rng::Pcg;

/// One request in a trace.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    /// Seconds from trace start.
    pub at: f64,
    /// Adapter name (None = base model request).
    pub adapter: Option<String>,
    pub domain: String,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
}

/// Trace generation parameters.
#[derive(Debug, Clone)]
pub struct TraceSpec {
    /// (adapter name, domain) pairs — adapter `i` gets share `i` of traffic.
    pub adapters: Vec<(String, String)>,
    /// Aggregate arrival rate λ (req/s) across all adapters.
    pub lambda: f64,
    /// Power-law shape α (1 = uniform across adapters).
    pub alpha: f64,
    /// Trace horizon in seconds.
    pub horizon: f64,
    pub vocab: usize,
    pub seed: u64,
}

/// A generated trace, sorted by arrival time.
#[derive(Debug, Clone)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
    pub spec_lambda: f64,
}

impl Trace {
    /// One independent Poisson process per adapter with rate
    /// `λ_i = share_i * λ`, merged and sorted (the paper's construction).
    pub fn generate(spec: &TraceSpec) -> Trace {
        let shares = power_law_shares(spec.adapters.len(), spec.alpha);
        let mut prompts = PromptGen::new(spec.vocab, spec.seed);
        let mut events = Vec::new();
        for (i, (name, domain)) in spec.adapters.iter().enumerate() {
            let lam_i = shares[i] * spec.lambda;
            if lam_i <= 0.0 {
                continue;
            }
            let mut rng = Pcg::with_stream(spec.seed, 9000 + i as u64);
            let mut t = rng.exp(lam_i);
            while t < spec.horizon {
                let (prompt, max_new) = prompts.sample(domain);
                events.push(TraceEvent {
                    at: t,
                    adapter: Some(name.clone()),
                    domain: domain.clone(),
                    prompt,
                    max_new_tokens: max_new,
                });
                t += rng.exp(lam_i);
            }
        }
        events.sort_by(|a, b| a.at.partial_cmp(&b.at).unwrap());
        Trace { events, spec_lambda: spec.lambda }
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Requests per adapter name (skew inspection).
    pub fn per_adapter_counts(&self) -> std::collections::BTreeMap<String, usize> {
        let mut m = std::collections::BTreeMap::new();
        for e in &self.events {
            *m.entry(e.adapter.clone().unwrap_or_else(|| "<base>".into()))
                .or_insert(0) += 1;
        }
        m
    }

    /// Scale all arrival times by `factor` (testbed slow-down).
    pub fn dilate(&mut self, factor: f64) {
        for e in &mut self.events {
            e.at *= factor;
        }
    }

    /// Clamp prompts/outputs to a model's bucket + KV budget (every
    /// replayer needs this before driving a small-geometry config).
    pub fn clip(&mut self, max_prompt: usize, max_new: usize) {
        for e in &mut self.events {
            e.prompt.truncate(max_prompt.max(1));
            e.max_new_tokens = e.max_new_tokens.clamp(1, max_new.max(1));
        }
    }

    /// Split into per-adapter traces (insertion order = first arrival),
    /// the input of a merged per-adapter deployment. Base-model events
    /// (`adapter == None`) are dropped — a merged instance cannot serve
    /// them.
    pub fn split_by_adapter(&self) -> Vec<(String, Trace)> {
        let mut order: Vec<String> = Vec::new();
        let mut by: std::collections::HashMap<String, Vec<TraceEvent>> =
            std::collections::HashMap::new();
        for e in &self.events {
            let Some(name) = &e.adapter else { continue };
            if !by.contains_key(name) {
                order.push(name.clone());
            }
            by.entry(name.clone()).or_default().push(e.clone());
        }
        order
            .into_iter()
            .map(|name| {
                let events = by.remove(&name).unwrap_or_default();
                (name, Trace { events, spec_lambda: self.spec_lambda })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(n: usize, lambda: f64, alpha: f64) -> TraceSpec {
        TraceSpec {
            adapters: (0..n)
                .map(|i| (format!("a{i}"), "math".to_string()))
                .collect(),
            lambda,
            alpha,
            horizon: 100.0,
            vocab: 8192,
            seed: 1,
        }
    }

    #[test]
    fn aggregate_rate_close_to_lambda() {
        let t = Trace::generate(&spec(5, 4.0, 1.0));
        let rate = t.len() as f64 / 100.0;
        assert!((rate - 4.0).abs() < 0.5, "rate {rate}");
    }

    #[test]
    fn sorted_by_time_within_horizon() {
        let t = Trace::generate(&spec(10, 2.0, 0.3));
        assert!(t.events.windows(2).all(|w| w[0].at <= w[1].at));
        assert!(t.events.iter().all(|e| e.at >= 0.0 && e.at < 100.0));
    }

    #[test]
    fn skew_shows_up_in_counts() {
        let t = Trace::generate(&spec(10, 10.0, 0.1));
        let counts = t.per_adapter_counts();
        let top = counts.get("a0").copied().unwrap_or(0);
        let total: usize = counts.values().sum();
        assert!(top as f64 / total as f64 > 0.5, "top share {top}/{total}");
    }

    #[test]
    fn clip_and_split_by_adapter() {
        let mut t = Trace::generate(&spec(3, 5.0, 0.5));
        t.clip(4, 2);
        assert!(t.events.iter().all(|e| e.prompt.len() <= 4));
        assert!(t.events.iter().all(|e| (1..=2).contains(&e.max_new_tokens)));

        let n = t.len();
        let parts = t.split_by_adapter();
        assert_eq!(parts.len(), 3);
        let total: usize = parts.iter().map(|(_, p)| p.len()).sum();
        assert_eq!(total, n);
        for (name, part) in &parts {
            assert!(part
                .events
                .iter()
                .all(|e| e.adapter.as_deref() == Some(name.as_str())));
            assert!(part.events.windows(2).all(|w| w[0].at <= w[1].at));
        }
    }

    #[test]
    fn deterministic_and_dilatable() {
        let a = Trace::generate(&spec(3, 3.0, 0.5));
        let b = Trace::generate(&spec(3, 3.0, 0.5));
        assert_eq!(a.len(), b.len());
        assert_eq!(a.events[0].prompt, b.events[0].prompt);
        let mut c = a.clone();
        c.dilate(2.0);
        assert!((c.events[5].at - 2.0 * a.events[5].at).abs() < 1e-9);
    }
}
