//! Adapter lifecycle under capacity pressure, end to end through the
//! engine (sim backend — no artifacts): registry + weight store
//! round-trips, LRU ordering, double-load rejection, and the
//! evict-while-running safety net.

use expertweave::adapters::format::Adapter;
use expertweave::adapters::generator::{paper_adapter_profiles, synth_adapter};
use expertweave::engine::{Engine, EngineOptions, RequestSpec};
use expertweave::model::ModelConfig;
use expertweave::runtime::{SimPerf, Variant};
use expertweave::sampler::SamplingParams;
use expertweave::weights::StoreMode;

fn cfg() -> ModelConfig {
    let mut c = ModelConfig::sim_default();
    c.max_adapters = 2; // tight capacity: pressure by construction
    c
}

fn adapter(cfg: &ModelConfig, name: &str, seed: u64) -> Adapter {
    let mut p = paper_adapter_profiles()[0].clone();
    p.max_experts = cfg.e_max;
    p.avg_experts = cfg.e_max as f64;
    let mut ad =
        synth_adapter(&p, cfg.layers, cfg.num_experts, cfg.hidden, cfg.expert_inter, seed);
    ad.name = name.to_string();
    ad
}

fn engine(cfg: &ModelConfig, adapters: &[Adapter]) -> Engine {
    Engine::sim_weave(
        cfg,
        SimPerf::fast(),
        adapters,
        Variant::Weave,
        StoreMode::Virtual,
        EngineOptions { page_size: 64 << 10, chunk: 32, ..Default::default() },
    )
    .unwrap()
}

fn req(adapter: &str, n: usize) -> RequestSpec {
    RequestSpec {
        adapter: Some(adapter.to_string()),
        prompt: vec![1, 2, 3, 4],
        max_new_tokens: n,
        sampling: SamplingParams::greedy(),
    }
}

#[test]
fn load_evict_round_trip_under_capacity_pressure() {
    let c = cfg();
    let (a, b, x) = (adapter(&c, "a", 1), adapter(&c, "b", 2), adapter(&c, "x", 3));
    let mut e = engine(&c, &[a.clone(), b.clone()]);
    assert_eq!(e.adapter_slots_total(), 2);
    assert!(e.has_adapter("a") && e.has_adapter("b"));

    // full: a third load must fail until something is evicted
    assert!(e.load_adapter(&x).is_err());
    assert_eq!(e.resident_adapters().count(), 2);

    // double-load of a resident adapter is rejected
    assert!(e.load_adapter(&a).is_err());

    // evict + reload round-trip frees and reuses the slot
    e.evict_adapter("a").unwrap();
    assert!(!e.has_adapter("a"));
    e.load_adapter(&x).unwrap();
    assert!(e.has_adapter("x"));
    // serving through the reloaded slot works
    e.submit(req("x", 2)).unwrap();
    let done = e.run_to_completion().unwrap();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].output.len(), 2);
}

#[test]
fn policy_capped_max_seqs_matches_step_abi() {
    // regression: the out_rows tensor length is part of the step ABI
    // (config max_seqs), independent of a lower engine admission cap
    let c = cfg();
    let a = adapter(&c, "a", 1);
    let mut e = Engine::sim_weave(
        &c,
        SimPerf::fast(),
        &[a],
        Variant::Weave,
        StoreMode::Virtual,
        EngineOptions { page_size: 64 << 10, max_seqs: 2, ..Default::default() },
    )
    .unwrap();
    for _ in 0..4 {
        e.submit(req("a", 2)).unwrap();
    }
    let done = e.run_to_completion().unwrap();
    assert_eq!(done.len(), 4);
}

#[test]
fn lru_order_follows_request_traffic() {
    let c = cfg();
    let (a, b) = (adapter(&c, "a", 1), adapter(&c, "b", 2));
    let mut e = engine(&c, &[a, b]);
    // traffic touches "a" most recently -> "b" is the LRU victim
    e.submit(req("b", 1)).unwrap();
    e.submit(req("a", 1)).unwrap();
    e.run_to_completion().unwrap();
    assert_eq!(e.lru_adapter().as_deref(), Some("b"));
    // new traffic to "b" flips the order
    e.submit(req("b", 1)).unwrap();
    e.run_to_completion().unwrap();
    assert_eq!(e.lru_adapter().as_deref(), Some("a"));
}

#[test]
fn evict_while_running_is_rejected() {
    let c = cfg();
    let (a, b) = (adapter(&c, "a", 1), adapter(&c, "b", 2));
    let mut e = engine(&c, &[a, b]);
    e.submit(req("a", 4)).unwrap();

    // queued (not yet stepped): eviction must already be refused
    let err = e.evict_adapter("a").unwrap_err();
    assert!(format!("{err:#}").contains("in flight"), "{err:#}");

    // mid-decode: still refused
    e.step().unwrap();
    assert!(e.evict_adapter("a").is_err());
    // the idle adapter can go at any time
    e.evict_adapter("b").unwrap();

    // after draining, the eviction goes through
    e.run_to_completion().unwrap();
    e.evict_adapter("a").unwrap();
    assert_eq!(e.resident_adapters().count(), 0);
    // and requests for it are rejected at submit
    assert!(e.submit(req("a", 1)).is_err());
}
