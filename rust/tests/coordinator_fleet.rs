//! Fleet coordinator end to end over the sim backend: routing,
//! load-on-miss lifecycle, admission control, accounting invariants,
//! and the fleet's serving-API surface (streaming, cancel, drain).

use expertweave::adapters::format::Adapter;
use expertweave::adapters::generator::synth_fleet_adapters;
use expertweave::coordinator::{Coordinator, CoordinatorConfig, RoutingPolicy};
use expertweave::engine::{Engine, EngineOptions};
use expertweave::model::ModelConfig;
use expertweave::runtime::{SimPerf, Variant};
use expertweave::sampler::SamplingParams;
use expertweave::serving::{
    AbortReason, ServeRequest, ServingBackend, SubmitError, TokenEvent,
};
use expertweave::weights::StoreMode;
use expertweave::workload::trace::{Trace, TraceEvent, TraceSpec};

fn cfg(capacity: usize) -> ModelConfig {
    let mut c = ModelConfig::sim_default();
    c.max_adapters = capacity;
    c
}

fn adapters(c: &ModelConfig, n: usize) -> Vec<Adapter> {
    synth_fleet_adapters(c, n, 42)
}

fn launch(c: &ModelConfig, coord_cfg: CoordinatorConfig, ads: Vec<Adapter>) -> Coordinator {
    let c = c.clone();
    Coordinator::launch(
        coord_cfg,
        move |i| {
            let cfg = c.clone();
            Box::new(move || {
                Engine::sim_weave(
                    &cfg,
                    SimPerf::fast(),
                    &[],
                    Variant::Weave,
                    StoreMode::Virtual,
                    EngineOptions { page_size: 64 << 10, chunk: 32, seed: i as u64, ..Default::default() },
                )
            })
        },
        ads,
    )
    .unwrap()
}

/// Hand-built trace: `burst` simultaneous arrivals for `name` at t=0
/// (arrivals outpace any possible completion, deterministically).
fn burst_trace(name: &str, domain: &str, burst: usize, vocab: usize) -> Trace {
    let events = (0..burst)
        .map(|_| TraceEvent {
            at: 0.0,
            adapter: Some(name.to_string()),
            domain: domain.to_string(),
            prompt: (1..=16).map(|t| t % vocab as i32).collect(),
            max_new_tokens: 8,
        })
        .collect();
    Trace { events, spec_lambda: 0.0 }
}

#[test]
fn fleet_serves_skewed_trace_with_full_accounting() {
    let c = cfg(2);
    let ads = adapters(&c, 4);
    let coord = launch(
        &c,
        CoordinatorConfig {
            replicas: 2,
            policy: RoutingPolicy::AdapterAffinity,
            adapter_capacity: 2,
            queue_cap: 0, // unbounded: everything must complete
            replicate_rps: f64::INFINITY,
            rate_halflife: 1.0,
            max_copies: 2,
            ..Default::default()
        },
        ads.clone(),
    );
    let mut trace = Trace::generate(&TraceSpec {
        adapters: ads.iter().map(|a| (a.name.clone(), a.domain.clone())).collect(),
        lambda: 30.0,
        alpha: 0.4,
        horizon: 1.0,
        vocab: c.vocab,
        seed: 3,
    });
    trace.clip(24, 4);
    let n = trace.len();
    assert!(n > 5, "trace too short: {n}");

    let outcome = coord.replay(&trace).unwrap();
    // conservation: every arrival is completed, shed, or rejected
    assert_eq!(
        outcome.completions.len() + outcome.stats.shed_total() + outcome.stats.submit_rejected,
        n
    );
    // 4 adapters over 2x2 slots: everything placeable, nothing shed
    assert_eq!(outcome.stats.shed_total(), 0);
    assert_eq!(outcome.stats.submit_rejected, 0);
    assert_eq!(outcome.completions.len(), n);
    assert_eq!(outcome.report.requests, n);
    assert_eq!(outcome.per_replica.len(), 2);
    let per_replica_sum: usize = outcome.per_replica.iter().map(|r| r.requests).sum();
    assert_eq!(per_replica_sum, n);
    // affinity on a fully-placed fleet: hits dominate
    assert!(outcome.stats.affinity_hits > 0);
    assert!(outcome.stats.hit_rate() > 0.8, "hit rate {}", outcome.stats.hit_rate());
    // initial placement loaded each adapter exactly once
    assert_eq!(outcome.stats.loads, 4);
    assert!(outcome.report.goodput() > 0.0);
}

#[test]
fn bounded_queues_shed_and_unknown_adapters_are_refused() {
    let c = cfg(2);
    let ads = adapters(&c, 2);
    let coord = launch(
        &c,
        CoordinatorConfig {
            replicas: 2,
            policy: RoutingPolicy::AdapterAffinity,
            adapter_capacity: 2,
            queue_cap: 2, // tiny budget against a burst
            replicate_rps: f64::INFINITY,
            rate_halflife: 1.0,
            max_copies: 2,
            ..Default::default()
        },
        ads.clone(),
    );
    let mut trace = burst_trace(&ads[0].name, &ads[0].domain, 12, c.vocab);
    // one request for an adapter nobody hosts
    trace.events.push(TraceEvent {
        at: 0.02,
        adapter: Some("ghost".into()),
        domain: "math".into(),
        prompt: vec![1, 2, 3],
        max_new_tokens: 2,
    });
    let n = trace.len();
    let outcome = coord.replay(&trace).unwrap();
    assert_eq!(
        outcome.completions.len() + outcome.stats.shed_total() + outcome.stats.submit_rejected,
        n
    );
    assert!(
        outcome.stats.shed_queue_full > 0,
        "burst of 12 against queue_cap=2 must shed: {:?}",
        outcome.stats
    );
    assert!(
        outcome.stats.submit_rejected >= 1,
        "ghost adapter must be a typed UnknownAdapter rejection: {:?}",
        outcome.stats
    );
    assert_eq!(outcome.report.shed, outcome.stats.shed_total());
    assert_eq!(outcome.report.rejected, outcome.stats.submit_rejected);
}

#[test]
fn hot_adapter_gets_replicated() {
    let c = cfg(2);
    let ads = adapters(&c, 2);
    let coord = launch(
        &c,
        CoordinatorConfig {
            replicas: 2,
            policy: RoutingPolicy::AdapterAffinity,
            adapter_capacity: 2, // one free slot per replica after placement
            queue_cap: 0,
            replicate_rps: 2.0, // trip the threshold quickly
            rate_halflife: 0.5,
            max_copies: 2,
            ..Default::default()
        },
        ads.clone(),
    );
    // a burst of 20 simultaneous arrivals on one adapter: the rate
    // estimate crosses the threshold on the second arrival, and the
    // remaining requests spread across both copies (least-inflight)
    let events = (0..20)
        .map(|_| TraceEvent {
            at: 0.0,
            adapter: Some(ads[0].name.clone()),
            domain: ads[0].domain.clone(),
            prompt: vec![1, 2, 3, 4],
            max_new_tokens: 4,
        })
        .collect();
    let trace = Trace { events, spec_lambda: 20.0 };
    let outcome = coord.replay(&trace).unwrap();
    assert!(
        outcome.stats.replications >= 1,
        "20 req/s vs threshold 2 req/s must replicate: {:?}",
        outcome.stats
    );
    assert_eq!(outcome.completions.len(), 20);
    // both replicas ended up serving it
    let served: usize = outcome
        .per_replica
        .iter()
        .filter(|r| r.requests > 0)
        .count();
    assert_eq!(served, 2, "replication must spread the hot adapter");
}

/// The fleet's serving-API surface used directly (no trace replay):
/// typed submits, per-token streaming across the replica boundary,
/// cancel relayed to the owning replica, drain + finish.
#[test]
fn fleet_serving_backend_streams_cancels_and_drains() {
    let c = cfg(2);
    let ads = adapters(&c, 2);
    let mut coord = launch(
        &c,
        CoordinatorConfig {
            replicas: 2,
            policy: RoutingPolicy::AdapterAffinity,
            adapter_capacity: 2,
            queue_cap: 0,
            replicate_rps: f64::INFINITY,
            rate_halflife: 1.0,
            max_copies: 2,
            ..Default::default()
        },
        ads.clone(),
    );
    let started = std::time::Instant::now();
    let req = |name: &str, max_new: usize| ServeRequest {
        adapter: Some(name.to_string()),
        prompt: (1..=8).collect(),
        max_new_tokens: max_new,
        sampling: SamplingParams::greedy(),
        deadline: None,
        trace: None,
    };

    // unknown adapter: typed rejection at the fleet door
    match coord.submit(req("ghost", 1)) {
        Err(SubmitError::UnknownAdapter(n)) => assert_eq!(n, "ghost"),
        other => panic!("expected UnknownAdapter, got {other:?}"),
    }

    // a long request streams tokens across the replica boundary
    let long = coord.submit(req(&ads[0].name, 2000)).unwrap();
    let mut events = Vec::new();
    for _ in 0..2000 {
        coord.pump().unwrap();
        events.extend(long.drain_events());
        if events.iter().any(|ev| matches!(ev, TokenEvent::First { .. })) {
            break;
        }
    }
    assert!(
        events.iter().any(|ev| matches!(ev, TokenEvent::First { .. })),
        "no First token streamed from the replica"
    );

    // cancel mid-decode: relayed to the replica, stream ends Aborted
    assert!(coord.cancel(long.id), "cancel must route to the replica");
    for _ in 0..2000 {
        coord.pump().unwrap();
        events.extend(long.drain_events());
        if events.iter().any(|ev| matches!(ev, TokenEvent::Aborted { .. })) {
            break;
        }
    }
    assert!(
        matches!(
            events.last(),
            Some(TokenEvent::Aborted { reason: AbortReason::Cancelled, .. })
        ),
        "stream must end Aborted(Cancelled): {} events",
        events.len()
    );

    // a short request completes with Done; drain waits for it
    let short = coord.submit(req(&ads[1].name, 3)).unwrap();
    coord.drain().unwrap();
    assert!(short
        .drain_events()
        .iter()
        .any(|ev| matches!(ev, TokenEvent::Done { .. })));
    match coord.submit(req(&ads[0].name, 1)) {
        Err(SubmitError::ShuttingDown) => {}
        other => panic!("post-drain submit must be ShuttingDown, got {other:?}"),
    }

    let (per_replica, stats) = coord.finish(started).unwrap();
    assert_eq!(per_replica.len(), 2);
    let aborted: usize = per_replica.iter().map(|r| r.aborted).sum();
    let completed: usize = per_replica.iter().map(|r| r.requests).sum();
    assert_eq!(aborted, 1, "the cancelled request is booked on its replica");
    assert_eq!(completed, 1);
    assert_eq!(stats.routed, 2);
    assert_eq!(
        stats.submit_rejected, 2,
        "ghost + the post-drain ShuttingDown refusal"
    );
}

#[test]
fn round_robin_thrashes_where_affinity_holds() {
    // 4 adapters, 2 replicas with capacity 2: affinity can keep its
    // initial placement perfect; round-robin must load-on-miss.
    let c = cfg(2);
    let ads = adapters(&c, 4);
    let trace = {
        let mut t = Trace::generate(&TraceSpec {
            adapters: ads.iter().map(|a| (a.name.clone(), a.domain.clone())).collect(),
            lambda: 25.0,
            alpha: 1.0, // uniform: every adapter active
            horizon: 1.0,
            vocab: c.vocab,
            seed: 11,
        });
        t.clip(16, 3);
        t
    };
    let run = |policy: RoutingPolicy| {
        let coord = launch(
            &c,
            CoordinatorConfig {
                replicas: 2,
                policy,
                adapter_capacity: 2,
                queue_cap: 0,
                replicate_rps: f64::INFINITY,
                rate_halflife: 1.0,
                max_copies: 2,
                ..Default::default()
            },
            ads.clone(),
        );
        coord.replay(&trace).unwrap()
    };
    let affinity = run(RoutingPolicy::AdapterAffinity);
    let rr = run(RoutingPolicy::RoundRobin);
    // affinity never needs a load beyond initial placement here
    assert_eq!(affinity.stats.loads, 4, "{:?}", affinity.stats);
    assert_eq!(affinity.stats.evictions, 0);
    assert!(
        rr.stats.loads > affinity.stats.loads,
        "rr loads {} vs affinity {}",
        rr.stats.loads,
        affinity.stats.loads
    );
    assert!(rr.stats.evictions > 0, "{:?}", rr.stats);
}
