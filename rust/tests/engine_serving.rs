//! Serving-engine integration over the tiny artifacts: request lifecycle,
//! continuous batching across adapters, greedy-output agreement between
//! ExpertWeave and merged instances (Table 3), and trace replay.

use expertweave::adapters::format::Adapter;
use expertweave::adapters::generator::{paper_adapter_profiles, synth_adapter};
use expertweave::engine::{Engine, EngineOptions, RequestSpec};
use expertweave::model::ModelConfig;
use expertweave::runtime::{ArtifactSet, Variant};
use expertweave::sampler::SamplingParams;
use expertweave::server;
use expertweave::weights::StoreMode;
use expertweave::workload::trace::{Trace, TraceSpec};
use std::path::PathBuf;

fn artifacts() -> Option<ArtifactSet> {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    d.join("meta.json")
        .exists()
        .then(|| ArtifactSet::load(&d).unwrap())
}

fn adapter(cfg: &ModelConfig, name: &'static str, seed: u64) -> Adapter {
    let mut p = paper_adapter_profiles()[0].clone();
    p.name = name;
    p.max_experts = cfg.e_max;
    p.avg_experts = cfg.e_max as f64;
    synth_adapter(&p, cfg.layers, cfg.num_experts, cfg.hidden, cfg.expert_inter, seed)
}

fn opts() -> EngineOptions {
    EngineOptions { page_size: 64 << 10, chunk: 8, ..Default::default() }
}

fn req(adapter: Option<&str>, prompt: Vec<i32>, n: usize) -> RequestSpec {
    RequestSpec {
        adapter: adapter.map(str::to_string),
        prompt,
        max_new_tokens: n,
        sampling: SamplingParams::greedy(),
    }
}

#[test]
fn engine_serving_end_to_end() {
    let Some(set) = artifacts() else {
        eprintln!("SKIP: artifacts/tiny missing");
        return;
    };
    let cfg = set.config.clone();
    let ad_a = adapter(&cfg, "math", 3);
    let ad_b = adapter(&cfg, "law", 4);

    // --- ExpertWeave engine with two adapters ---------------------------
    let mut weave = Engine::new_weave(
        &set,
        &[ad_a.clone(), ad_b.clone()],
        Variant::Weave,
        StoreMode::Virtual,
        opts(),
    )
    .unwrap();

    // 1) interleaved multi-adapter + base requests complete
    let prompts: Vec<Vec<i32>> = (0..6)
        .map(|i| (1..=(5 + i as i32 * 3)).map(|t| t % cfg.vocab as i32).collect())
        .collect();
    let mut ids = Vec::new();
    for (i, p) in prompts.iter().enumerate() {
        let who = match i % 3 {
            0 => Some("math"),
            1 => Some("law"),
            _ => None,
        };
        ids.push(weave.submit(req(who, p.clone(), 4)).unwrap());
    }
    let done = weave.run_to_completion().unwrap();
    assert_eq!(done.len(), 6);
    for c in &done {
        assert_eq!(c.output.len(), 4);
        assert!(c.output.iter().all(|&t| t >= 0 && (t as usize) < cfg.vocab));
    }
    assert_eq!(weave.kv_free_slots(), cfg.kv_cap, "KV slots must drain");
    let report = weave.report();
    assert_eq!(report.requests, 6);
    assert!(report.ttft.median > 0.0);

    // 2) unknown adapter rejected
    assert!(weave.submit(req(Some("nope"), vec![1, 2], 1)).is_err());

    // 3) greedy agreement with the merged instance (Table 3 mechanism):
    // same prompt through weave/math and through a merged math engine
    // must yield the same tokens.
    let p: Vec<i32> = (1..=10).collect();
    let w_id = weave.submit(req(Some("math"), p.clone(), 6)).unwrap();
    let w_out = weave
        .run_to_completion()
        .unwrap()
        .into_iter()
        .find(|c| c.id == w_id)
        .unwrap();

    let mut merged = Engine::new_merged(&set, ad_a.clone(), opts()).unwrap();
    let m_id = merged.submit(req(Some("math"), p.clone(), 6)).unwrap();
    let m_out = merged
        .run_to_completion()
        .unwrap()
        .into_iter()
        .find(|c| c.id == m_id)
        .unwrap();
    assert_eq!(w_out.output, m_out.output, "weave must match merged greedily");

    // 4) ...and the base-only engine disagrees (the adapter does matter)
    let mut base = Engine::new_base_only(&set, opts()).unwrap();
    let b_id = base.submit(req(None, p.clone(), 6)).unwrap();
    let b_out = base
        .run_to_completion()
        .unwrap()
        .into_iter()
        .find(|c| c.id == b_id)
        .unwrap();
    assert_ne!(w_out.output, b_out.output, "adapter output should differ from base");

    // 5) dynamic adapter lifecycle
    let ad_c = adapter(&cfg, "intent", 5);
    weave.load_adapter(&ad_c).unwrap();
    let id = weave.submit(req(Some("intent"), p.clone(), 2)).unwrap();
    let out = weave.run_to_completion().unwrap();
    assert!(out.iter().any(|c| c.id == id));
    weave.evict_adapter("intent").unwrap();
    assert!(weave.submit(req(Some("intent"), p, 1)).is_err());

    // 6) trace replay (short horizon, both adapters)
    let trace = Trace::generate(&TraceSpec {
        adapters: vec![
            ("math".into(), "math".into()),
            ("law".into(), "law".into()),
        ],
        lambda: 20.0,
        alpha: 0.5,
        horizon: 0.5,
        vocab: cfg.vocab,
        seed: 7,
    });
    // tiny model: clip prompts to the bucket budget
    let mut trace = trace;
    for e in &mut trace.events {
        e.prompt.truncate(12);
        e.max_new_tokens = e.max_new_tokens.min(3);
    }
    let n = trace.len();
    assert!(n > 0);
    let outcome = server::replay(&mut weave, &trace).unwrap();
    assert_eq!(outcome.completions.len(), n);
    assert_eq!(outcome.rejected, 0);
    assert!(outcome.report.decode_throughput > 0.0);
}
