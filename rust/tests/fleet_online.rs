//! Online fleet serving end to end: deadline-aware routing over live
//! replicas (split EWMA signal), typed deadline rejections, the fleet
//! behind the NDJSON TCP frontend (submit/stream/cancel/drain over ≥2
//! sim replicas, driven through [`NdjsonClient`]), the open-loop load
//! generator, and the membership/chaos suite — replica death mid-run
//! (failover re-routing, typed `ReplicaLost` aborts, zero lost
//! streams), runtime join via [`Coordinator::add_replica`], and
//! drain-and-retire via [`Coordinator::retire_replica`].

use expertweave::adapters::generator::synth_fleet_adapters;
use expertweave::coordinator::{Coordinator, CoordinatorConfig, RoutingPolicy};
use expertweave::engine::{Engine, EngineOptions};
use expertweave::model::ModelConfig;
use expertweave::runtime::{SimPerf, Variant};
use expertweave::sampler::SamplingParams;
use expertweave::serving::frontend::{NdjsonClient, NdjsonServer};
use expertweave::serving::{
    AbortReason, RequestHandle, ServeRequest, ServingBackend, SubmitError, TokenEvent,
};
use expertweave::weights::StoreMode;
use expertweave::workload::openloop::{self, OpenLoopSpec};
use std::time::Duration;

fn req(adapter: Option<&str>, prompt_len: usize, max_new: usize) -> ServeRequest {
    ServeRequest {
        adapter: adapter.map(str::to_string),
        prompt: (1..=prompt_len as i32).collect(),
        max_new_tokens: max_new,
        sampling: SamplingParams::greedy(),
        deadline: None,
        trace: None,
    }
}

/// Pump a backend, folding a handle's events into `events`, until the
/// predicate holds (or panic after a generous bound).
fn pump_until<B: ServingBackend>(
    backend: &mut B,
    handle: &RequestHandle,
    events: &mut Vec<TokenEvent>,
    what: &str,
    pred: impl Fn(&[TokenEvent]) -> bool,
) {
    for _ in 0..30_000 {
        let _ = backend.pump().unwrap();
        events.extend(handle.drain_events());
        if pred(events) {
            return;
        }
    }
    panic!("never reached: {what} ({} events)", events.len());
}

/// Pump until every handle's stream reached a terminal event, folding
/// each stream into its `events` slot (or panic after a generous bound).
fn pump_all<B: ServingBackend>(
    backend: &mut B,
    handles: &[RequestHandle],
    events: &mut [Vec<TokenEvent>],
    what: &str,
) {
    for _ in 0..30_000 {
        let _ = backend.pump().unwrap();
        for (h, evs) in handles.iter().zip(events.iter_mut()) {
            evs.extend(h.drain_events());
        }
        if events.iter().all(|evs| evs.iter().any(|e| e.is_terminal())) {
            return;
        }
    }
    let open = events.iter().filter(|e| !e.iter().any(|ev| ev.is_terminal())).count();
    panic!("never reached: {what} ({open} stream(s) still open)");
}

fn has_first(evs: &[TokenEvent]) -> bool {
    evs.iter().any(|e| matches!(e, TokenEvent::First { .. }))
}

fn has_done(evs: &[TokenEvent]) -> bool {
    evs.iter().any(|e| matches!(e, TokenEvent::Done { .. }))
}

/// The ISSUE scenario: replica 0 is *slow* (inflated decode EWMA) while
/// replica 1 is fast, and both carry one in-flight request each — so
/// queue depth alone cannot tell them apart. DeadlineAware reads the
/// published expected wait, routes the deadline-bound request to the
/// fast replica, and it completes inside its deadline; a deadline no
/// replica can meet is refused with a typed error instead of expiring
/// in a queue.
#[test]
fn deadline_aware_routes_around_slow_replica() {
    let cfg = ModelConfig::sim_default();
    let slow = SimPerf {
        step_base: Duration::from_millis(400),
        per_token: Duration::ZERO,
        adapter_swap: Duration::from_millis(1),
    };
    let spawn_cfg = cfg.clone();
    let mut coord = Coordinator::launch(
        CoordinatorConfig {
            replicas: 2,
            policy: RoutingPolicy::DeadlineAware,
            adapter_capacity: 2,
            queue_cap: 0,
            replicate_rps: f64::INFINITY,
            rate_halflife: 1.0,
            max_copies: 2,
            ..Default::default()
        },
        move |i| {
            let cfg = spawn_cfg.clone();
            let perf = if i == 0 { slow } else { SimPerf::fast() };
            Box::new(move || {
                Engine::sim_weave(
                    &cfg,
                    perf,
                    &[],
                    Variant::Weave,
                    StoreMode::Virtual,
                    EngineOptions { page_size: 64 << 10, seed: i as u64, ..Default::default() },
                )
            })
        },
        Vec::new(), // base-model requests only: residency plays no role
    )
    .unwrap();
    let started = std::time::Instant::now();

    // prime both EWMAs: A lands on replica 0 (all-idle tie breaks by
    // index), B on replica 1 (A is in flight); run both to completion
    let a = coord.submit(req(None, 4, 3)).unwrap();
    let b = coord.submit(req(None, 4, 3)).unwrap();
    let mut evs_a = Vec::new();
    pump_until(&mut coord, &a, &mut evs_a, "prime A done", has_done);
    let mut evs_b = Vec::new();
    pump_until(&mut coord, &b, &mut evs_b, "prime B done", has_done);

    // occupy both replicas with one long request each: equal in-flight
    // counts, wildly different expected waits
    let c = coord.submit(req(None, 4, 1000)).unwrap();
    let d = coord.submit(req(None, 4, 1000)).unwrap();
    let mut evs_c = Vec::new();
    pump_until(&mut coord, &c, &mut evs_c, "C decoding", has_first);
    let mut evs_d = Vec::new();
    pump_until(&mut coord, &d, &mut evs_d, "D decoding", has_first);

    // a deadline only the fast replica can meet: replica 0's expected
    // wait is its ~400 ms decode EWMA x 1 in-flight, replica 1's is
    // sub-millisecond (the 200 ms budget leaves generous wall-clock
    // slack for loaded CI runners)
    let mut tight = req(None, 4, 2);
    tight.deadline = Some(Duration::from_millis(200));
    let e = coord.submit(tight).unwrap();
    let mut evs_e = Vec::new();
    pump_until(&mut coord, &e, &mut evs_e, "tight-deadline done", |evs| {
        evs.iter().any(|ev| ev.is_terminal())
    });
    assert!(
        has_done(&evs_e),
        "the deadline request must complete on the fast replica: {evs_e:?}"
    );

    // a deadline nobody can meet (below even the fast replica's
    // one-step EWMA) is refused with the typed error at the door
    let mut hopeless = req(None, 4, 1);
    hopeless.deadline = Some(Duration::from_micros(10));
    match coord.submit(hopeless) {
        Err(SubmitError::DeadlineUnmeetable) => {}
        other => panic!("expected DeadlineUnmeetable, got {other:?}"),
    }

    // tear down: cancel the occupants, drain, and check the books
    assert!(coord.cancel(c.id));
    assert!(coord.cancel(d.id));
    ServingBackend::drain(&mut coord).unwrap();
    let (per_replica, stats) = coord.finish(started).unwrap();
    assert_eq!(per_replica.len(), 2);
    assert_eq!(
        per_replica[1].requests, 2,
        "the fast replica served its prime + the deadline request"
    );
    assert_eq!(per_replica[0].requests, 1, "the slow replica served only its prime");
    let missed: usize = per_replica.iter().map(|r| r.deadline_missed).sum();
    assert_eq!(missed, 0, "nothing routed by DeadlineAware may expire here");
    assert_eq!(stats.deadline_unmeetable, 1);
    assert_eq!(stats.routed, 5);
}

/// The fleet behind the TCP frontend, exercised through [`NdjsonClient`]
/// (both halves of the wire in one test): submit + stream over ≥2 sim
/// replicas, typed error for an unknown adapter, cancel relayed across
/// the replica boundary, and — the regression this file exists for —
/// a drain that completes all in-flight work on *every* replica before
/// the listener closes.
#[test]
fn fleet_ndjson_tcp_serve_stream_cancel_drain() {
    let cfg = ModelConfig::sim_default();
    let adapters = synth_fleet_adapters(&cfg, 2, 42);
    let names: Vec<String> = adapters.iter().map(|a| a.name.clone()).collect();

    let server = NdjsonServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let spawn_cfg = cfg.clone();
    let serving = std::thread::spawn(move || {
        let started = std::time::Instant::now();
        let mut coord = Coordinator::launch(
            CoordinatorConfig {
                replicas: 2,
                policy: RoutingPolicy::AdapterAffinity,
                adapter_capacity: 2,
                queue_cap: 0,
                replicate_rps: f64::INFINITY,
                rate_halflife: 1.0,
                max_copies: 2,
                ..Default::default()
            },
            move |i| {
                let cfg = spawn_cfg.clone();
                Box::new(move || {
                    Engine::sim_weave(
                        &cfg,
                        SimPerf::fast(),
                        &[],
                        Variant::Weave,
                        StoreMode::Virtual,
                        EngineOptions {
                            page_size: 64 << 10,
                            chunk: 32,
                            seed: i as u64,
                            ..Default::default()
                        },
                    )
                })
            },
            adapters,
        )
        .unwrap();
        server.run(&mut coord).unwrap();
        // every replica drained before run() returned; finish() only
        // collects reports and joins the threads
        coord.finish(started).unwrap()
    });

    let mut client = NdjsonClient::connect(&addr.to_string()).unwrap();

    // 1) one request per adapter, streamed to completion across replicas
    let h1 = client.submit(req(Some(&names[0]), 6, 3)).unwrap();
    let h2 = client.submit(req(Some(&names[1]), 6, 3)).unwrap();
    let mut evs1 = Vec::new();
    pump_until(&mut client, &h1, &mut evs1, "r1 done", has_done);
    let mut evs2 = Vec::new();
    pump_until(&mut client, &h2, &mut evs2, "r2 done", has_done);
    assert!(has_first(&evs1), "TTFT edge must be visible on the wire");
    let Some(TokenEvent::Done { completion, .. }) =
        evs1.iter().find(|e| matches!(e, TokenEvent::Done { .. }))
    else {
        unreachable!()
    };
    assert_eq!(completion.output.len(), 3);
    assert_eq!(completion.record.prompt_tokens, 6);

    // 2) unknown adapter: the fleet door's typed rejection crosses the
    // wire as an error frame and surfaces as Aborted(Rejected)
    let ghost = client.submit(req(Some("ghost"), 4, 1)).unwrap();
    let mut evs_g = Vec::new();
    pump_until(&mut client, &ghost, &mut evs_g, "ghost rejected", |evs| {
        evs.iter().any(|e| e.is_terminal())
    });
    assert!(
        matches!(
            evs_g.last(),
            Some(TokenEvent::Aborted {
                reason: AbortReason::Rejected(SubmitError::UnknownAdapter(_)),
                ..
            })
        ),
        "expected a typed unknown-adapter rejection: {evs_g:?}"
    );

    // 3) cancel mid-decode, relayed coordinator → owning replica
    let long = client.submit(req(Some(&names[0]), 6, 2000)).unwrap();
    let mut evs_l = Vec::new();
    pump_until(&mut client, &long, &mut evs_l, "long decoding", has_first);
    assert!(client.cancel(long.id));
    pump_until(&mut client, &long, &mut evs_l, "long aborted", |evs| {
        evs.iter().any(|e| e.is_terminal())
    });
    assert!(matches!(
        evs_l.last(),
        Some(TokenEvent::Aborted { reason: AbortReason::Cancelled, .. })
    ));

    // 4) drain with work still in flight: the submit races the drain
    // down the same pipe, so the fleet must finish it on whichever
    // replica it landed before acknowledging
    let last = client.submit(req(Some(&names[1]), 6, 4)).unwrap();
    ServingBackend::drain(&mut client).unwrap();
    assert!(client.is_drained());
    assert!(
        has_done(&last.drain_events()),
        "drain must complete in-flight work before the ack"
    );
    match client.submit(req(None, 2, 1)) {
        Err(SubmitError::ShuttingDown) => {}
        other => panic!("post-drain submit must fail ShuttingDown, got {other:?}"),
    }

    let (per_replica, stats) = serving.join().unwrap();
    assert_eq!(per_replica.len(), 2);
    let completed: usize = per_replica.iter().map(|r| r.requests).sum();
    let aborted: usize = per_replica.iter().map(|r| r.aborted).sum();
    assert_eq!(completed, 3, "r1 + r2 + the post-drain-race request");
    assert_eq!(aborted, 1, "the cancelled long request");
    assert!(stats.submit_rejected >= 1, "ghost: {stats:?}");
    // both replicas actually served (affinity spread the two adapters)
    assert!(per_replica.iter().all(|r| r.requests > 0), "{per_replica:?}");
}

/// Open-loop generator sanity against a single sim engine: arrivals are
/// injected for the whole horizon regardless of completions, and every
/// offered request is accounted for exactly once.
#[test]
fn open_loop_accounts_for_every_arrival() {
    let cfg = ModelConfig::sim_default();
    let adapters = synth_fleet_adapters(&cfg, 2, 42);
    let mut engine = Engine::sim_weave(
        &cfg,
        SimPerf::fast(),
        &adapters,
        Variant::Weave,
        StoreMode::Virtual,
        EngineOptions { page_size: 64 << 10, ..Default::default() },
    )
    .unwrap();
    let spec = OpenLoopSpec {
        rate: 150.0,
        horizon: 0.4,
        adapters: adapters.iter().map(|a| a.name.clone()).collect(),
        alpha: 0.5,
        prompt_len: 12,
        max_new: 4,
        deadline: None,
        vocab: cfg.vocab,
        prefix_overlap: 0.0,
        sampled_frac: 0.5,
        seed: 7,
    };
    let outcome = openloop::drive(&mut engine, &spec).unwrap();
    assert!(outcome.offered > 20, "~60 arrivals expected, got {}", outcome.offered);
    assert_eq!(
        outcome.completed
            + outcome.rejected
            + outcome.deadline_unmeetable
            + outcome.deadline_expired
            + outcome.replica_lost
            + outcome.aborted_other,
        outcome.offered,
        "every arrival is completed, rejected, or missed: {outcome:?}"
    );
    assert_eq!(outcome.completed, outcome.offered, "no deadline, no overload: all done");
    assert_eq!(outcome.ttft.n, outcome.completed);
    // the session spans (most of) the arrival horizon plus the drain
    // tail; the last Poisson gap may cross the horizon slightly early
    assert!(outcome.wall > spec.horizon * 0.5, "wall {}", outcome.wall);
    assert!(outcome.deadline_miss_rate() == 0.0);
    // the engine's own books agree
    let report = engine.report();
    assert_eq!(report.requests, outcome.completed);
}

/// Determinism across deployment shapes (protocol v5): the same seeded
/// sampled request produces a byte-identical token stream on a solo
/// [`Engine`] and on a fleet replica built with the same engine seed —
/// the sampler's PRNG is keyed only by the request seed, and the sim's
/// pseudo-logits only by the engine seed, so neither the coordinator
/// hop nor the replica thread may perturb the stream.
#[test]
fn seeded_sampling_matches_between_solo_engine_and_fleet_replica() {
    let cfg = ModelConfig::sim_default();
    let sampled_req = || {
        let mut r = req(None, 6, 12);
        r.sampling = SamplingParams::top_p(0.9, 0.8).with_seed(0xD1CE);
        r
    };
    let output_of = |evs: &[TokenEvent]| -> Vec<i32> {
        let done = evs
            .iter()
            .find_map(|e| match e {
                TokenEvent::Done { completion, .. } => Some(completion.output.clone()),
                _ => None,
            })
            .expect("stream completed");
        // the incremental First/Token view must agree with the completion
        let streamed: Vec<i32> = evs
            .iter()
            .filter_map(|e| match e {
                TokenEvent::First { token, .. } | TokenEvent::Token { token, .. } => {
                    Some(*token)
                }
                _ => None,
            })
            .collect();
        assert_eq!(streamed, done, "streamed tokens must match the completion");
        done
    };

    // solo engine with seed 0 — the same engine seed replica 0 gets below
    let mut engine = Engine::sim_weave(
        &cfg,
        SimPerf::fast(),
        &[],
        Variant::Weave,
        StoreMode::Virtual,
        EngineOptions { page_size: 64 << 10, seed: 0, ..Default::default() },
    )
    .unwrap();
    let h = engine.submit_request(sampled_req()).unwrap();
    let mut evs = Vec::new();
    pump_until(&mut engine, &h, &mut evs, "solo sampled done", has_done);
    let solo = output_of(&evs);
    assert_eq!(solo.len(), 12);

    // one-replica fleet: same model config, replica seeds are their index
    let spawn_cfg = cfg.clone();
    let mut coord = Coordinator::launch(
        CoordinatorConfig {
            replicas: 1,
            policy: RoutingPolicy::RoundRobin,
            adapter_capacity: 2,
            queue_cap: 0,
            replicate_rps: f64::INFINITY,
            rate_halflife: 1.0,
            max_copies: 1,
            ..Default::default()
        },
        move |i| {
            let cfg = spawn_cfg.clone();
            Box::new(move || {
                Engine::sim_weave(
                    &cfg,
                    SimPerf::fast(),
                    &[],
                    Variant::Weave,
                    StoreMode::Virtual,
                    EngineOptions { page_size: 64 << 10, seed: i as u64, ..Default::default() },
                )
            })
        },
        Vec::new(),
    )
    .unwrap();
    let started = std::time::Instant::now();
    let h = coord.submit(sampled_req()).unwrap();
    let mut evs = Vec::new();
    pump_until(&mut coord, &h, &mut evs, "fleet sampled done", has_done);
    let fleet = output_of(&evs);
    ServingBackend::drain(&mut coord).unwrap();
    coord.finish(started).unwrap();

    assert_eq!(solo, fleet, "request seed + engine seed must pin the sampled stream");
}

/// Chaos: a 3-replica fleet where replica 0's sim engine crashes
/// deterministically mid-run (`sim_fail_after`). Every submitted
/// stream must still reach a terminal event — with no deadlines
/// attached, every request routed to the doomed replica is re-routed
/// to a survivor and completes — the fleet keeps accepting submits
/// after the loss, and the books show the failover.
#[test]
fn chaos_replica_death_reroutes_without_lost_streams() {
    let cfg = ModelConfig::sim_default();
    let spawn_cfg = cfg.clone();
    let mut coord = Coordinator::launch(
        CoordinatorConfig {
            replicas: 3,
            policy: RoutingPolicy::RoundRobin,
            adapter_capacity: 2,
            queue_cap: 0,
            replicate_rps: f64::INFINITY,
            rate_halflife: 1.0,
            max_copies: 2,
            ..Default::default()
        },
        move |i| {
            let cfg = spawn_cfg.clone();
            Box::new(move || {
                Engine::sim_weave(
                    &cfg,
                    SimPerf::fast(),
                    &[],
                    Variant::Weave,
                    StoreMode::Virtual,
                    EngineOptions {
                        page_size: 64 << 10,
                        seed: i as u64,
                        // replica 0 dies after a dozen device steps —
                        // mid-decode for the batch below
                        sim_fail_after: if i == 0 { 12 } else { 0 },
                        ..Default::default()
                    },
                )
            })
        },
        Vec::new(), // base-model traffic: residency plays no role here
    )
    .unwrap();
    let started = std::time::Instant::now();

    // round-robin spreads the batch over all three replicas, so the
    // doomed one holds work when it dies (max_new 24 > 12 fail steps:
    // nothing it was given can complete before the crash)
    let handles: Vec<RequestHandle> =
        (0..12).map(|_| coord.submit(req(None, 6, 24)).unwrap()).collect();
    let mut events: Vec<Vec<TokenEvent>> = vec![Vec::new(); handles.len()];
    pump_all(&mut coord, &handles, &mut events, "all streams settle across the crash");

    // zero lost streams, and every re-route lands (no deadline to miss)
    let done = events.iter().filter(|e| has_done(e)).count();
    assert_eq!(done, handles.len(), "every request completes: {events:?}");

    // the fleet keeps serving with the survivors
    assert_eq!(coord.live_count(), 2);
    let after = coord.submit(req(None, 4, 2)).unwrap();
    let mut evs = Vec::new();
    pump_until(&mut coord, &after, &mut evs, "post-crash submit done", has_done);

    ServingBackend::drain(&mut coord).unwrap();
    let (per_replica, stats) = coord.finish(started).unwrap();
    assert_eq!(per_replica.len(), 3, "the dead replica keeps its (empty) report slot");
    assert_eq!(stats.replica_retired, 1);
    assert!(stats.requests_rerouted >= 1, "the doomed replica held work: {stats:?}");
    assert_eq!(stats.reroute_aborted, 0, "no deadlines -> every re-route lands");
    let completed: usize = per_replica.iter().map(|r| r.requests).sum();
    assert_eq!(completed, 13, "12 batch + 1 post-crash, all on survivors: {per_replica:?}");
}

/// Runtime membership: a replica added mid-run ([`Coordinator::
/// add_replica`]) takes its share of traffic, and drain-and-retire
/// ([`Coordinator::retire_replica`]) removes the founder without
/// losing its report.
#[test]
fn runtime_join_and_retire_shift_traffic() {
    let cfg = ModelConfig::sim_default();
    let spawn_cfg = cfg.clone();
    let engine_for = |seed: u64| {
        let cfg = cfg.clone();
        move || {
            Engine::sim_weave(
                &cfg,
                SimPerf::fast(),
                &[],
                Variant::Weave,
                StoreMode::Virtual,
                EngineOptions { page_size: 64 << 10, seed, ..Default::default() },
            )
        }
    };
    let mut coord = Coordinator::launch(
        CoordinatorConfig {
            replicas: 1,
            policy: RoutingPolicy::RoundRobin,
            adapter_capacity: 2,
            queue_cap: 0,
            replicate_rps: f64::INFINITY,
            rate_halflife: 1.0,
            max_copies: 1,
            ..Default::default()
        },
        move |i| {
            let cfg = spawn_cfg.clone();
            Box::new(move || {
                Engine::sim_weave(
                    &cfg,
                    SimPerf::fast(),
                    &[],
                    Variant::Weave,
                    StoreMode::Virtual,
                    EngineOptions { page_size: 64 << 10, seed: i as u64, ..Default::default() },
                )
            })
        },
        Vec::new(),
    )
    .unwrap();
    let started = std::time::Instant::now();

    // pre-join traffic lands on the only replica
    let a = coord.submit(req(None, 4, 2)).unwrap();
    let mut evs_a = Vec::new();
    pump_until(&mut coord, &a, &mut evs_a, "pre-join done", has_done);

    // join: a fresh engine thread spun up mid-run, index append-only
    let ix = coord.add_replica(Box::new(engine_for(1))).unwrap();
    assert_eq!(ix, 1);
    assert_eq!(coord.live_count(), 2);

    // round-robin now alternates across both replicas
    let handles: Vec<RequestHandle> =
        (0..6).map(|_| coord.submit(req(None, 4, 2)).unwrap()).collect();
    let mut events: Vec<Vec<TokenEvent>> = vec![Vec::new(); handles.len()];
    pump_all(&mut coord, &handles, &mut events, "post-join batch done");
    assert!(events.iter().all(|e| has_done(e)), "{events:?}");

    // drain-and-retire the founder: remaining traffic flows to the
    // newcomer, and the founder's report survives the retire
    coord.retire_replica(0).unwrap();
    assert_eq!(coord.live_count(), 1);
    let b = coord.submit(req(None, 4, 2)).unwrap();
    let mut evs_b = Vec::new();
    pump_until(&mut coord, &b, &mut evs_b, "post-retire done", has_done);

    let (per_replica, stats) = coord.finish(started).unwrap();
    assert_eq!(per_replica.len(), 2);
    assert!(per_replica[1].requests >= 3, "the newcomer serves traffic: {per_replica:?}");
    let completed: usize = per_replica.iter().map(|r| r.requests).sum();
    assert_eq!(completed, 8, "retire must not drop the founder's report: {per_replica:?}");
    assert_eq!(stats.routed, 8);
    assert_eq!(stats.replica_retired, 1);
    assert_eq!(stats.requests_rerouted, 0, "a clean retire re-routes nothing");
}

/// The kill-switch regression this PR removes: killing the *only*
/// replica mid-decode must surface a typed [`AbortReason::ReplicaLost`]
/// terminal on the in-flight stream — never a hang — and later submits
/// shed typed instead of poisoning the coordinator fatally.
#[test]
fn kill_only_replica_aborts_typed_not_fatal() {
    let cfg = ModelConfig::sim_default();
    let spawn_cfg = cfg.clone();
    let mut coord = Coordinator::launch(
        CoordinatorConfig {
            replicas: 1,
            policy: RoutingPolicy::RoundRobin,
            adapter_capacity: 2,
            queue_cap: 0,
            replicate_rps: f64::INFINITY,
            rate_halflife: 1.0,
            max_copies: 1,
            ..Default::default()
        },
        move |i| {
            let cfg = spawn_cfg.clone();
            Box::new(move || {
                Engine::sim_weave(
                    &cfg,
                    SimPerf::fast(),
                    &[],
                    Variant::Weave,
                    StoreMode::Virtual,
                    EngineOptions { page_size: 64 << 10, seed: i as u64, ..Default::default() },
                )
            })
        },
        Vec::new(),
    )
    .unwrap();
    let started = std::time::Instant::now();

    let h = coord.submit(req(None, 4, 2000)).unwrap();
    let mut evs = Vec::new();
    pump_until(&mut coord, &h, &mut evs, "victim decoding", has_first);

    // fault injection: die as if the engine had crashed
    assert!(coord.kill_replica(0));
    pump_until(&mut coord, &h, &mut evs, "typed terminal after kill", |evs| {
        evs.iter().any(|e| e.is_terminal())
    });
    assert!(
        matches!(
            evs.last(),
            Some(TokenEvent::Aborted { reason: AbortReason::ReplicaLost, .. })
        ),
        "no survivor to re-route to -> typed ReplicaLost: {evs:?}"
    );

    // the fleet is degraded, not poisoned: submits shed typed
    assert_eq!(coord.live_count(), 0);
    match coord.submit(req(None, 2, 1)) {
        Err(SubmitError::Shed) => {}
        other => panic!("expected Shed with no live replicas, got {other:?}"),
    }

    let (per_replica, stats) = coord.finish(started).unwrap();
    assert_eq!(per_replica.len(), 1);
    assert_eq!(stats.replica_retired, 1);
    assert_eq!(stats.reroute_aborted, 1);
}
