// De-risk probe: can xla_extension 0.5.1 parse jax-0.8-generated HLO text
// containing while loops, scatter, pallas-interpret output and
// input_output_alias? Run: cargo test --test hlo_probe -- --ignored
// Skips itself when the probe artifact or a real PJRT build is absent
// (the vendored `xla` stub cannot compile HLO).
#[test]
#[ignore]
fn parse_and_run_probe4() {
    if !std::path::Path::new("/tmp/probe4.hlo.txt").exists() {
        eprintln!("SKIP: /tmp/probe4.hlo.txt missing (python AOT probe not run)");
        return;
    }
    let client = xla::PjRtClient::cpu().unwrap();
    let proto = xla::HloModuleProto::from_text_file("/tmp/probe4.hlo.txt").unwrap();
    let comp = xla::XlaComputation::from_proto(&proto);
    match client.compile(&comp) {
        Ok(_) => println!("probe4 compiled OK"),
        // the vendored stub cannot compile anything: skip. A real
        // xla_extension failing to parse/compile is the probe's finding.
        Err(e) if e.to_string().contains("xla stub") => eprintln!("SKIP: {e}"),
        Err(e) => panic!("probe4 failed to compile: {e}"),
    }
}
