// De-risk probe: can xla_extension 0.5.1 parse jax-0.8-generated HLO text
// containing while loops, scatter, pallas-interpret output and
// input_output_alias? Run: cargo test --test hlo_probe -- --ignored
#[test]
#[ignore]
fn parse_and_run_probe4() {
    let client = xla::PjRtClient::cpu().unwrap();
    let proto = xla::HloModuleProto::from_text_file("/tmp/probe4.hlo.txt").unwrap();
    let comp = xla::XlaComputation::from_proto(&proto);
    let _exe = client.compile(&comp).unwrap();
    println!("probe4 compiled OK");
}
