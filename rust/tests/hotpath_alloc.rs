//! The zero-allocation contract of the step pipeline: once a serving
//! session reaches steady-state decode (all sequences admitted and past
//! prefill, buffers at capacity), `Engine::step` must perform ZERO heap
//! allocations — the batch is packed into the persistent
//! [`StepWorkspace`], KV slots come out of `alloc_into`, the sim backend
//! reads greedy tokens straight off the row hash into the persistent
//! [`StepOutput`], and metrics push into pre-reserved sample buffers.
//!
//! Gated behind the `alloc-counter` feature (Cargo `required-features`)
//! so the counting global allocator never leaks into normal test runs:
//!
//! ```text
//! cargo test --features alloc-counter --test hotpath_alloc -- --nocapture
//! ```
//!
//! A second phase inside the same test re-proves the contract for a
//! *mixed* greedy + temperature + top-p batch: the logits path with
//! per-request sampler slots ([`SamplerBank`]) must be just as
//! allocation-free as the O(1) greedy path.
//!
//! This file holds exactly one #[test] so no concurrent test can pollute
//! the global allocation counter.
//!
//! [`SamplerBank`]: expertweave::sampler::SamplerBank

use expertweave::adapters::generator::synth_fleet_adapters;
use expertweave::engine::{Engine, EngineOptions, RequestSpec};
use expertweave::model::ModelConfig;
use expertweave::runtime::{SimPerf, Variant};
use expertweave::sampler::SamplingParams;
use expertweave::util::alloc_counter::{allocations, CountingAlloc};
use expertweave::weights::StoreMode;
use std::time::Instant;

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_decode_performs_zero_allocations() {
    const SEQS: usize = 8;
    const PROMPT: usize = 4;
    const WARMUP: usize = 32;
    const MEASURE: usize = 64;
    const MAX_NEW: usize = WARMUP + MEASURE + 32;

    let mut cfg = ModelConfig::sim_default();
    cfg.kv_cap = SEQS * (PROMPT + MAX_NEW + 8);
    let adapters = synth_fleet_adapters(&cfg, 2, 42);
    let mut e = Engine::sim_weave(
        &cfg,
        SimPerf::instant(),
        &adapters,
        Variant::Weave,
        StoreMode::Virtual,
        EngineOptions { page_size: 64 << 10, ..Default::default() },
    )
    .unwrap();
    e.metrics.reserve_steps(WARMUP + MEASURE + 16);
    for i in 0..SEQS {
        // mix adapter and base traffic so the fused reroute runs on a
        // heterogeneous AID batch, like real serving
        let who = (i % 2 == 0).then(|| adapters[i / 2 % 2].name.clone());
        e.submit(RequestSpec {
            adapter: who,
            prompt: (1..=PROMPT as i32).collect(),
            max_new_tokens: MAX_NEW,
            sampling: SamplingParams::greedy(),
        })
        .unwrap();
    }
    // warmup: prefill completes, dead token streams detach, every
    // workspace/output/KV buffer reaches steady-state capacity
    for _ in 0..WARMUP {
        e.step().unwrap();
    }
    let (waiting, running) = e.queue_depth();
    assert_eq!(waiting, 0, "all sequences must be admitted");
    assert_eq!(running, SEQS, "all sequences must still be decoding");

    // the obs registry must be live during the measured window — the
    // zero-allocation contract includes metric recording, not a
    // telemetry-off fast path
    let obs = e.obs();
    assert!(obs.is_enabled(), "recording must be on while we measure");
    let obs_before = obs.snapshot();
    // the always-on flight recorder is part of the contract too: its
    // ring must absorb one Step event per step without allocating
    let flightrec = e.flight_recorder();
    let flightrec_before = flightrec.recorded();

    let before = allocations();
    let t0 = Instant::now();
    for _ in 0..MEASURE {
        e.step().unwrap();
    }
    let elapsed = t0.elapsed();
    let after = allocations();

    assert_eq!(
        after - before,
        0,
        "steady-state decode must not allocate (got {} allocations over {MEASURE} steps)",
        after - before
    );

    // recording demonstrably happened across the alloc-free window:
    // step counters and per-adapter token counters both advanced
    let obs_after = obs.snapshot();
    assert_eq!(
        obs_after.steps - obs_before.steps,
        MEASURE as u64,
        "every measured step must be recorded"
    );
    assert_eq!(
        obs_after.tokens_decode - obs_before.tokens_decode,
        (MEASURE * SEQS) as u64,
        "every decode token must be counted"
    );
    assert_eq!(
        obs_after.step_wall_us.count - obs_before.step_wall_us.count,
        MEASURE as u64,
        "every step wall time must land in the histogram"
    );
    assert!(
        flightrec.recorded() - flightrec_before >= MEASURE as u64,
        "the flight recorder must capture every measured step (got {} of {MEASURE})",
        flightrec.recorded() - flightrec_before
    );
    for name in ["base", &adapters[0].name, &adapters[1].name] {
        let tokens = |s: &expertweave::obs::StatsSnapshot| {
            s.adapters.iter().find(|a| a.name == name).map_or(0, |a| a.tokens)
        };
        assert!(
            tokens(&obs_after) > tokens(&obs_before),
            "adapter {name:?} token counter must advance during decode"
        );
    }
    let steps_per_sec = MEASURE as f64 / elapsed.as_secs_f64().max(1e-12);
    assert!(steps_per_sec > 0.0, "steps/sec must be nonzero");
    println!(
        "hotpath: {steps_per_sec:.0} steps/s, 0 allocations over {MEASURE} steady steps"
    );

    // sanity: the session still drains and completes everything
    let done = e.run_to_completion().unwrap();
    assert_eq!(done.len(), SEQS);
    assert!(done.iter().all(|c| c.output.len() == MAX_NEW));

    // ----- phase 2: mixed greedy + sampled batch, same contract -----
    //
    // A third of the rows decode greedily, a third sample with plain
    // temperature, a third through the nucleus filter — every step now
    // takes the logits path with per-row dispatch, per-slot PRNGs, and
    // the shared sort scratch. The zero-allocation contract must hold
    // for this mixture too (the ISSUE's production-sampling claim).
    e.metrics.reserve_steps(WARMUP + MEASURE + MAX_NEW + 16);
    for i in 0..SEQS {
        let who = (i % 2 == 0).then(|| adapters[i / 2 % 2].name.clone());
        let sampling = match i % 3 {
            0 => SamplingParams::greedy(),
            1 => SamplingParams::temperature(0.8).with_seed(100 + i as u64),
            _ => SamplingParams::top_p(0.9, 0.8).with_seed(100 + i as u64),
        };
        e.submit(RequestSpec {
            adapter: who,
            prompt: (1..=PROMPT as i32).collect(),
            max_new_tokens: MAX_NEW,
            sampling,
        })
        .unwrap();
    }
    // warmup: prefill completes and the logits buffer reaches its
    // steady capacity (the greedy phase never materialized logits)
    for _ in 0..WARMUP {
        e.step().unwrap();
    }
    let (waiting, running) = e.queue_depth();
    assert_eq!(waiting, 0, "mixed batch must be admitted");
    assert_eq!(running, SEQS, "mixed batch must still be decoding");
    let obs_before = obs.snapshot();

    let before = allocations();
    let t0 = Instant::now();
    for _ in 0..MEASURE {
        e.step().unwrap();
    }
    let elapsed = t0.elapsed();
    let after = allocations();
    assert_eq!(
        after - before,
        0,
        "mixed greedy+sampled decode must not allocate (got {} allocations over {MEASURE} steps)",
        after - before
    );

    let obs_after = obs.snapshot();
    assert_eq!(
        obs_after.steps - obs_before.steps,
        MEASURE as u64,
        "every mixed step must be recorded"
    );
    assert_eq!(
        obs_after.tokens_decode - obs_before.tokens_decode,
        (MEASURE * SEQS) as u64,
        "every greedy and sampled token must be counted"
    );
    let steps_per_sec = MEASURE as f64 / elapsed.as_secs_f64().max(1e-12);
    println!(
        "hotpath/mixed: {steps_per_sec:.0} steps/s, 0 allocations over {MEASURE} mixed steps"
    );

    // the mixed session drains and completes everything too
    let done = e.run_to_completion().unwrap();
    assert_eq!(done.len(), SEQS);
    assert!(done.iter().all(|c| c.output.len() == MAX_NEW));
}
