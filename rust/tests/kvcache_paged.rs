//! Paged-KV integration through the public engine API: prefix sharing
//! must be semantically invisible (same greedy streams as the private-
//! slot baseline), copy-on-write must fire when a shared partial block
//! diverges, and every exit path — completion, cancel, deadline expiry
//! — must return the cache to its idle capacity (refcounts never leak).

use expertweave::adapters::format::Adapter;
use expertweave::adapters::generator::synth_fleet_adapters;
use expertweave::engine::{Engine, EngineOptions, RequestSpec};
use expertweave::model::ModelConfig;
use expertweave::runtime::{SimPerf, Variant};
use expertweave::sampler::SamplingParams;
use expertweave::serving::ServeRequest;
use expertweave::weights::StoreMode;
use expertweave::workload::preamble_token;
use std::time::Duration;

fn engine(kv_cap: usize, share: bool, chunk: usize) -> (Engine, Vec<Adapter>) {
    let mut cfg = ModelConfig::sim_default();
    cfg.kv_cap = kv_cap;
    let adapters = synth_fleet_adapters(&cfg, 2, 42);
    let e = Engine::sim_weave(
        &cfg,
        SimPerf::instant(),
        &adapters,
        Variant::Weave,
        StoreMode::Virtual,
        EngineOptions {
            page_size: 64 << 10,
            chunk,
            kv_share: share,
            ..Default::default()
        },
    )
    .unwrap();
    (e, adapters)
}

/// `len`-token prompt whose first `shared` positions come from adapter
/// slot 0's preamble pool and whose tail is a private per-`i` stream.
fn prompt(i: u64, len: usize, shared: usize) -> Vec<i32> {
    (0..len)
        .map(|p| {
            if p < shared {
                preamble_token(0, 0, p, 512)
            } else {
                preamble_token(0x4000 + i, 7, p, 512)
            }
        })
        .collect()
}

fn spec(adapter: &Adapter, prompt: Vec<i32>, max_new: usize) -> RequestSpec {
    RequestSpec {
        adapter: Some(adapter.name.clone()),
        prompt,
        max_new_tokens: max_new,
        sampling: SamplingParams::greedy(),
    }
}

/// Submit six overlapping requests and drain; return (outputs by id,
/// prefix hit tokens). Identical workload under both cache policies.
fn run_fleet(share: bool) -> (Vec<(u64, Vec<i32>)>, u64) {
    let (mut e, adapters) = engine(512, share, 256);
    let idle = e.kv_free_slots();
    // sharing attaches at admission against blocks already computed by
    // live sequences, so let a seed request seal the shared block first
    e.submit(spec(&adapters[0], prompt(0, 24, 16), 4)).unwrap();
    e.step().unwrap();
    for i in 1..6u64 {
        // 24-token prompts sharing one full 16-token block
        e.submit(spec(&adapters[0], prompt(i, 24, 16), 4)).unwrap();
    }
    let mut done: Vec<(u64, Vec<i32>)> = e
        .run_to_completion()
        .unwrap()
        .into_iter()
        .map(|c| (c.id, c.output))
        .collect();
    done.sort_by_key(|(id, _)| *id);
    assert_eq!(e.kv_free_slots(), idle, "slots leaked (share={share})");
    (done, e.stats_snapshot().kv_prefix_hits)
}

#[test]
fn sharing_is_semantically_invisible_and_leak_free() {
    let (flat, flat_hits) = run_fleet(false);
    let (shared, shared_hits) = run_fleet(true);
    assert_eq!(flat.len(), 6);
    assert_eq!(flat, shared, "prefix sharing changed a greedy stream");
    assert_eq!(flat_hits, 0, "flat mode must never report prefix hits");
    assert!(
        shared_hits >= 16 * 5,
        "five of six requests should attach the shared block: {shared_hits}"
    );
}

#[test]
fn cancel_mid_flight_releases_shared_pages() {
    let (mut e, adapters) = engine(512, true, 256);
    let idle = e.kv_free_slots();
    // seed first so the flood's admission probe finds sealed blocks
    let seed = e.submit(spec(&adapters[0], prompt(0, 32, 32), 16)).unwrap();
    e.step().unwrap();
    let mut ids = vec![seed];
    ids.extend((1..4u64).map(|i| {
        e.submit(spec(&adapters[0], prompt(i, 32, 32), 16)).unwrap()
    }));
    e.step().unwrap();
    assert!(
        e.stats_snapshot().kv_pages_shared > 0,
        "expected live shared pages before cancelling"
    );
    assert!(e.cancel_request(ids[0]));
    assert!(e.cancel_request(ids[2]));
    assert!(!e.cancel_request(ids[0]), "double cancel must be a no-op");
    assert!(!e.cancel_request(9999));
    let done = e.run_to_completion().unwrap();
    assert_eq!(done.len(), 2, "the two uncancelled requests complete");
    assert_eq!(e.kv_free_slots(), idle, "cancel leaked shared KV pages");
    assert_eq!(e.queue_depth(), (0, 0));
}

#[test]
fn deadline_expiry_releases_shared_pages() {
    let (mut e, adapters) = engine(512, true, 256);
    let idle = e.kv_free_slots();
    // two requests sharing their whole 32-token prompt prefix: one
    // bounded (submitted first so its sealed blocks are attachable),
    // one that cannot finish before its deadline
    e.submit(spec(&adapters[0], prompt(0, 32, 32), 8)).unwrap();
    e.step().unwrap();
    let doomed = ServeRequest {
        adapter: Some(adapters[0].name.clone()),
        prompt: prompt(1, 32, 32),
        max_new_tokens: 400,
        sampling: SamplingParams::greedy(),
        deadline: Some(Duration::from_millis(25)),
        trace: None,
    };
    e.submit_request(doomed).unwrap();
    // both admitted and decoding against the shared prefix
    e.step().unwrap();
    e.step().unwrap();
    assert_eq!(e.queue_depth().1, 2);
    std::thread::sleep(Duration::from_millis(40));
    let done = e.run_to_completion().unwrap();
    assert_eq!(done.len(), 1, "only the bounded request completes");
    assert_eq!(done[0].output.len(), 8);
    assert_eq!(e.queue_depth(), (0, 0), "expired request still in flight");
    assert_eq!(e.kv_free_slots(), idle, "deadline expiry leaked KV pages");
}

#[test]
fn cow_divergence_keeps_both_streams_intact() {
    // chunk 8 so request A registers a half-filled tail block after one
    // step; B attaches that partial block and the next append into it
    // (refcount 2) must copy-on-write, not corrupt the other stream
    let run = |share: bool| -> (Vec<(u64, Vec<i32>)>, u64, usize) {
        let (mut e, adapters) = engine(512, share, 8);
        let idle = e.kv_free_slots();
        e.submit(spec(&adapters[0], prompt(0, 26, 26), 6)).unwrap();
        e.step().unwrap(); // A prefills exactly its first 8 tokens
        e.submit(spec(&adapters[0], prompt(1, 20, 8), 6)).unwrap();
        let mut done: Vec<(u64, Vec<i32>)> = e
            .run_to_completion()
            .unwrap()
            .into_iter()
            .map(|c| (c.id, c.output))
            .collect();
        done.sort_by_key(|(id, _)| *id);
        assert_eq!(e.kv_free_slots(), idle, "slots leaked (share={share})");
        (done, e.stats_snapshot().kv_pages_cow, e.kv_free_slots())
    };
    let (flat, flat_cow, _) = run(false);
    let (shared, shared_cow, _) = run(true);
    assert_eq!(flat.len(), 2);
    assert_eq!(flat, shared, "COW divergence corrupted a stream");
    assert_eq!(flat_cow, 0);
    assert!(shared_cow >= 1, "divergent append into a shared partial block must COW");
}

#[test]
fn cancelling_a_waiting_request_holds_no_kv() {
    // 64-slot cache: one 40+8 request fills it, the rest must wait
    let (mut e, adapters) = engine(64, true, 256);
    let idle = e.kv_free_slots();
    let ids: Vec<u64> = (0..3u64)
        .map(|i| e.submit(spec(&adapters[0], prompt(i, 40, 32), 8)).unwrap())
        .collect();
    e.step().unwrap();
    let (waiting, running) = e.queue_depth();
    assert_eq!(running, 1, "only one request fits the 64-slot cache");
    assert_eq!(waiting, 2);
    assert!(e.cancel_request(ids[2]), "cancel straight out of the queue");
    let done = e.run_to_completion().unwrap();
    assert_eq!(done.len(), 2);
    assert_eq!(e.kv_free_slots(), idle);
}
