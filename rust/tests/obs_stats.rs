//! End-to-end observability: the NDJSON `stats` frame and the Prometheus
//! exposition must agree while the backend is *live* (undrained, still
//! accepting requests) — for a single engine and for a multi-replica
//! fleet. Both surfaces read the same lock-free registries
//! ([`expertweave::obs::ObsRegistry`]); consistency here is the proof
//! that the per-adapter label plumbing (engine slots, replica merge)
//! lines up end to end.

use expertweave::adapters::generator::synth_fleet_adapters;
use expertweave::coordinator::{Coordinator, CoordinatorConfig, RoutingPolicy};
use expertweave::engine::{Engine, EngineOptions};
use expertweave::model::ModelConfig;
use expertweave::obs::expo::{render, scrape, MetricsListener};
use expertweave::obs::ObsRegistry;
use expertweave::runtime::{SimPerf, Variant};
use expertweave::serving::frontend::NdjsonServer;
use expertweave::util::json::Json;
use expertweave::weights::StoreMode;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

fn adapter_names() -> Vec<String> {
    let cfg = ModelConfig::sim_default();
    synth_fleet_adapters(&cfg, 2, 42).iter().map(|a| a.name.clone()).collect()
}

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let writer = stream.try_clone().unwrap();
        Client { reader: BufReader::new(stream), writer }
    }

    fn send(&mut self, line: &str) {
        writeln!(self.writer, "{line}").unwrap();
    }

    fn next_event(&mut self) -> Json {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).unwrap();
        assert!(n > 0, "server closed the connection unexpectedly");
        Json::parse(line.trim()).unwrap()
    }

    fn wait_for(&mut self, id: &str, event: &str) -> Json {
        for _ in 0..10_000 {
            let ev = self.next_event();
            if ev.get("id").and_then(|i| i.as_str()) == Some(id)
                && ev.get("event").and_then(|e| e.as_str()) == Some(event)
            {
                return ev;
            }
        }
        panic!("no {event:?} event for {id:?}");
    }

    fn drain(&mut self) {
        self.send(r#"{"op":"drain"}"#);
        loop {
            let ev = self.next_event();
            if ev.get("event").and_then(|e| e.as_str()) == Some("drained") {
                return;
            }
        }
    }
}

/// `completed` count for one adapter out of a stats frame.
fn frame_adapter_completed(frame: &Json, adapter: &str) -> i64 {
    frame
        .at(&["adapters"])
        .as_arr()
        .unwrap()
        .iter()
        .find(|a| a.at(&["adapter"]).as_str() == Some(adapter))
        .unwrap_or_else(|| panic!("adapter {adapter:?} missing from stats frame: {frame}"))
        .at(&["completed"])
        .as_i64()
        .unwrap()
}

/// `completed` count for one adapter out of a Prometheus page.
fn prom_adapter_completed(page: &str, adapter: &str) -> i64 {
    let needle =
        format!("expertweave_adapter_requests_completed_total{{adapter=\"{adapter}\"}} ");
    page.lines()
        .find_map(|l| l.strip_prefix(needle.as_str()))
        .unwrap_or_else(|| panic!("no completed family for {adapter:?} in:\n{page}"))
        .trim()
        .parse()
        .unwrap()
}

/// Sum of a per-replica counter family across all replica labels.
fn prom_family_total(page: &str, family: &str) -> i64 {
    let prefix = format!("{family}{{");
    page.lines()
        .filter(|l| l.starts_with(prefix.as_str()))
        .map(|l| l.rsplit(' ').next().unwrap().parse::<i64>().unwrap())
        .sum()
}

#[test]
fn live_engine_stats_frame_matches_prometheus_scrape() {
    let server = NdjsonServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    // the engine lives entirely on the serving thread; its obs registry
    // crosses back over a channel for the metrics listener to read
    let (obs_tx, obs_rx) = std::sync::mpsc::channel::<Arc<ObsRegistry>>();
    let serving = std::thread::spawn(move || {
        let cfg = ModelConfig::sim_default();
        let adapters = synth_fleet_adapters(&cfg, 2, 42);
        let mut engine = Engine::sim_weave(
            &cfg,
            SimPerf::fast(),
            &adapters,
            Variant::Weave,
            StoreMode::Virtual,
            EngineOptions { page_size: 64 << 10, ..Default::default() },
        )
        .unwrap();
        obs_tx.send(engine.obs()).unwrap();
        server.run(&mut engine).unwrap();
    });
    let obs = obs_rx.recv().unwrap();
    let regs = vec![obs];
    let metrics = MetricsListener::spawn("127.0.0.1:0", move || render(&regs)).unwrap();
    let names = adapter_names();

    let mut c = Client::connect(addr);
    c.send(&format!(
        r#"{{"id":"r1","adapter":"{}","prompt":[1,2,3,4],"max_new_tokens":3}}"#,
        names[0]
    ));
    c.send(r#"{"id":"r2","prompt":[5,6],"max_new_tokens":2}"#);
    c.wait_for("r1", "done");
    c.wait_for("r2", "done");

    // the engine is live (no drain yet): both surfaces must answer now
    c.send(r#"{"op":"stats","id":"s1"}"#);
    let frame = c.wait_for("s1", "stats");
    assert_eq!(
        frame.at(&["version"]).as_i64(),
        Some(expertweave::obs::STATS_VERSION)
    );
    assert_eq!(frame.at(&["replicas"]).as_i64(), Some(1));
    assert_eq!(frame.at(&["counters", "requests_completed"]).as_i64(), Some(2));
    assert_eq!(frame.at(&["counters", "requests_submitted"]).as_i64(), Some(2));
    assert!(frame.at(&["counters", "steps"]).as_i64().unwrap() > 0);
    assert!(frame.at(&["latency_us", "e2e", "p50"]).as_i64().unwrap() > 0);
    assert!(frame.get("fleet").is_none(), "single engine has no fleet section");

    let page = scrape(&metrics.local_addr()).unwrap();
    assert_eq!(prom_family_total(&page, "expertweave_requests_completed_total"), 2);

    // build identity and process uptime lead every exposition page
    assert!(page.contains("expertweave_build_info{version=\""), "build_info missing:\n{page}");
    assert!(page.contains(",git=\""), "build_info must carry a git label");
    assert!(page.contains("expertweave_uptime_seconds "), "uptime gauge missing");

    // per-adapter counters agree across the two surfaces
    let from_frame = frame_adapter_completed(&frame, &names[0]);
    let from_prom = prom_adapter_completed(&page, &names[0]);
    assert_eq!(from_frame, 1, "one request completed on {:?}", names[0]);
    assert_eq!(from_frame, from_prom, "stats frame and exposition must agree");
    let base_frame = frame_adapter_completed(&frame, "base");
    assert_eq!(base_frame, 1, "the no-adapter request lands on \"base\"");
    assert_eq!(base_frame, prom_adapter_completed(&page, "base"));

    c.drain();
    drop(c);
    serving.join().unwrap();
}

#[test]
fn live_fleet_stats_merge_replicas_and_match_prometheus() {
    let server = NdjsonServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let (obs_tx, obs_rx) = std::sync::mpsc::channel::<Vec<Arc<ObsRegistry>>>();
    let serving = std::thread::spawn(move || {
        let cfg = ModelConfig::sim_default();
        let adapters = synth_fleet_adapters(&cfg, 2, 42);
        let coord_cfg = CoordinatorConfig {
            replicas: 2,
            policy: RoutingPolicy::AdapterAffinity,
            adapter_capacity: 2,
            ..Default::default()
        };
        let spawn_cfg = cfg.clone();
        let mut coord = Coordinator::launch(
            coord_cfg,
            move |i| {
                let cfg = spawn_cfg.clone();
                Box::new(move || {
                    Engine::sim_weave(
                        &cfg,
                        SimPerf::fast(),
                        &[],
                        Variant::Weave,
                        StoreMode::Virtual,
                        EngineOptions {
                            page_size: 64 << 10,
                            seed: i as u64,
                            ..Default::default()
                        },
                    )
                })
            },
            adapters,
        )
        .unwrap();
        obs_tx.send(coord.obs_registries()).unwrap();
        server.run(&mut coord).unwrap();
        let started = std::time::Instant::now();
        coord.finish(started).unwrap();
    });
    let regs = obs_rx.recv().unwrap();
    assert_eq!(regs.len(), 2, "one registry per replica");
    let render_regs = regs.clone();
    let metrics =
        MetricsListener::spawn("127.0.0.1:0", move || render(&render_regs)).unwrap();
    let names = adapter_names();

    let mut c = Client::connect(addr);
    for (i, name) in names.iter().enumerate() {
        c.send(&format!(
            r#"{{"id":"f{i}","adapter":"{name}","prompt":[1,2,3],"max_new_tokens":2}}"#
        ));
    }
    for i in 0..names.len() {
        c.wait_for(&format!("f{i}"), "done");
    }

    // fleet is live: the stats frame merges both replica registries and
    // carries the coordinator's door counters
    c.send(r#"{"op":"stats","id":"fs"}"#);
    let frame = c.wait_for("fs", "stats");
    assert_eq!(
        frame.at(&["version"]).as_i64(),
        Some(expertweave::obs::STATS_VERSION)
    );
    assert_eq!(frame.at(&["replicas"]).as_i64(), Some(2));
    assert_eq!(
        frame.at(&["counters", "requests_completed"]).as_i64(),
        Some(names.len() as i64)
    );
    assert_eq!(frame.at(&["fleet", "routed"]).as_i64(), Some(names.len() as i64));
    assert_eq!(frame.at(&["fleet", "shed_queue_full"]).as_i64(), Some(0));

    let page = scrape(&metrics.local_addr()).unwrap();
    // per-replica families are labeled, and the sum across replicas
    // equals the frame's merged counter
    assert!(page.contains("expertweave_steps_total{replica=\"1\"}"));
    assert_eq!(
        prom_family_total(&page, "expertweave_requests_completed_total"),
        names.len() as i64
    );
    // per-adapter families agree between the two surfaces, replica-merged
    for name in &names {
        let from_frame = frame_adapter_completed(&frame, name);
        assert_eq!(from_frame, 1, "one request completed on {name:?}");
        assert_eq!(
            from_frame,
            prom_adapter_completed(&page, name),
            "fleet stats frame and exposition must agree for {name:?}"
        );
    }

    c.drain();
    drop(c);
    serving.join().unwrap();
}
