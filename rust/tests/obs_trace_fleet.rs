//! End-to-end distributed tracing: a 2-replica fleet served over TCP,
//! with tracing enabled at the coordinator, must produce ONE merged
//! Chrome-trace timeline in which a single request can be followed from
//! the fleet door to the replica that decoded it:
//!
//!   door_admission (pid 0) → routing_decision naming the chosen
//!   replica (pid 0) → that replica's queued/admitted/prefill/decode
//!   phase spans (pid = replica + 1), all carrying the same trace id.
//!
//! The trace id is client-supplied via the NDJSON `trace` field
//! (PROTOCOL.md v3); requests that omit it get the fleet request id.
//! The same session also exercises the `{"op":"flightrec"}` frame: the
//! always-on black-box ring must answer with per-replica event windows
//! without any opt-in.

use expertweave::adapters::generator::synth_fleet_adapters;
use expertweave::coordinator::{Coordinator, CoordinatorConfig, RoutingPolicy};
use expertweave::engine::{Engine, EngineOptions};
use expertweave::model::ModelConfig;
use expertweave::runtime::{SimPerf, Variant};
use expertweave::serving::frontend::NdjsonServer;
use expertweave::util::json::Json;
use expertweave::weights::StoreMode;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

/// The client-chosen end-to-end trace id for the request we follow.
const TRACE_ID: i64 = 777;

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let writer = stream.try_clone().unwrap();
        Client { reader: BufReader::new(stream), writer }
    }

    fn send(&mut self, line: &str) {
        writeln!(self.writer, "{line}").unwrap();
    }

    fn next_event(&mut self) -> Json {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).unwrap();
        assert!(n > 0, "server closed the connection unexpectedly");
        Json::parse(line.trim()).unwrap()
    }

    fn wait_for(&mut self, id: &str, event: &str) -> Json {
        for _ in 0..10_000 {
            let ev = self.next_event();
            if ev.get("id").and_then(|i| i.as_str()) == Some(id)
                && ev.get("event").and_then(|e| e.as_str()) == Some(event)
            {
                return ev;
            }
        }
        panic!("no {event:?} event for {id:?}");
    }

    fn drain(&mut self) {
        self.send(r#"{"op":"drain"}"#);
        loop {
            let ev = self.next_event();
            if ev.get("event").and_then(|e| e.as_str()) == Some("drained") {
                return;
            }
        }
    }
}

#[test]
fn fleet_trace_follows_one_request_door_to_decode() {
    let server = NdjsonServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let serving = std::thread::spawn(move || {
        let cfg = ModelConfig::sim_default();
        let adapters = synth_fleet_adapters(&cfg, 2, 42);
        let coord_cfg = CoordinatorConfig {
            replicas: 2,
            policy: RoutingPolicy::AdapterAffinity,
            adapter_capacity: 2,
            ..Default::default()
        };
        let spawn_cfg = cfg.clone();
        let mut coord = Coordinator::launch(
            coord_cfg,
            move |i| {
                let cfg = spawn_cfg.clone();
                Box::new(move || {
                    Engine::sim_weave(
                        &cfg,
                        SimPerf::fast(),
                        &[],
                        Variant::Weave,
                        StoreMode::Virtual,
                        EngineOptions {
                            page_size: 64 << 10,
                            seed: i as u64,
                            ..Default::default()
                        },
                    )
                })
            },
            adapters,
        )
        .unwrap();
        // before any client request: every request of the session traces
        coord.enable_trace().unwrap();
        server.run(&mut coord).unwrap();
        let started = std::time::Instant::now();
        coord.finish_traced(started).unwrap()
    });

    let cfg = ModelConfig::sim_default();
    let names: Vec<String> =
        synth_fleet_adapters(&cfg, 2, 42).iter().map(|a| a.name.clone()).collect();

    let mut c = Client::connect(addr);
    // the request we follow: client-supplied trace id, adapter traffic
    c.send(&format!(
        r#"{{"id":"r1","adapter":"{}","prompt":[1,2,3,4],"max_new_tokens":4,"trace":{TRACE_ID}}}"#,
        names[0]
    ));
    // a second request without a trace id: defaults to the fleet rid
    c.send(r#"{"id":"r2","prompt":[5,6,7],"max_new_tokens":2}"#);
    c.wait_for("r1", "done");
    c.wait_for("r2", "done");

    // the black-box is always on: no opt-in, answered while live
    c.send(r#"{"op":"flightrec","id":"fr"}"#);
    let frame = c.wait_for("fr", "flightrec");
    let replicas = frame.at(&["replicas"]).as_arr().unwrap();
    assert_eq!(replicas.len(), 2, "one ring per replica");
    let recorded: i64 =
        replicas.iter().map(|r| r.at(&["recorded"]).as_i64().unwrap()).sum();
    assert!(recorded > 0, "the fleet served requests, the rings must have seen them");
    let kinds: Vec<&str> = replicas
        .iter()
        .flat_map(|r| r.at(&["events"]).as_arr().unwrap().iter())
        .filter_map(|e| e.at(&["kind"]).as_str())
        .collect();
    assert!(kinds.contains(&"submit"), "submit events in the ring: {kinds:?}");
    assert!(kinds.contains(&"done"), "done events in the ring: {kinds:?}");

    c.drain();
    drop(c);
    let (per_replica, _stats, trace) = serving.join().unwrap();
    assert_eq!(per_replica.len(), 2);
    let trace = trace.expect("enable_trace ran, finish_traced must return the merged log");

    // --- coordinator side: the routing decision for our trace id ---
    assert_eq!(trace.routes().len(), 2, "both requests were routed");
    let route = trace
        .routes()
        .iter()
        .find(|r| r.trace == TRACE_ID as u64)
        .expect("a RouteSpan must carry the client-supplied trace id");
    assert_eq!(route.policy, "adapter-affinity");
    assert_eq!(route.adapter, names[0]);
    assert!(route.replica < 2, "chosen replica must be a real index");
    assert_eq!(route.candidates.len(), 2, "the full scored candidate set is kept");
    assert!(route.admitted_us >= route.arrival_us);
    assert!(route.routed_us >= route.admitted_us);
    // the request without a client trace id defaulted to its fleet rid
    let other = trace.routes().iter().find(|r| r.trace != TRACE_ID as u64).unwrap();
    assert_eq!(other.trace, other.rid, "no client id: trace id = fleet rid");

    // --- replica side: the phase span merged under the fleet track ---
    let span = trace
        .spans()
        .iter()
        .find(|s| s.trace == TRACE_ID as u64)
        .expect("the replica's phase span must carry the same trace id");
    assert_eq!(span.id, route.rid, "replica-local id re-keyed to the fleet rid");
    assert_eq!(
        span.pid,
        route.replica as u64 + 1,
        "the span renders under the replica the router actually chose"
    );
    assert_eq!(span.outcome, "done");
    assert_eq!(span.adapter, names[0]);
    assert!(span.first_scheduled_us.is_some(), "prefill phase must be stamped");
    assert!(span.prefill_done_us.is_some(), "decode phase must be stamped");
    assert!(span.finished_us >= span.arrival_us);
    // door-side routing completed before the replica finished the request
    assert!(route.routed_us <= span.finished_us);

    // --- the rendered Chrome-trace document ties it all together ---
    let doc = Json::parse(&trace.to_chrome_json().to_string()).unwrap();
    let events = doc.at(&["traceEvents"]).as_arr().unwrap();
    let of = |name: &str| {
        events
            .iter()
            .find(|e| {
                e.at(&["name"]).as_str() == Some(name)
                    && e.at(&["args", "trace"]).as_i64() == Some(TRACE_ID)
            })
            .unwrap_or_else(|| panic!("no {name:?} event with trace {TRACE_ID}"))
    };
    let door = of("door_admission");
    assert_eq!(door.at(&["pid"]).as_i64(), Some(0), "door span on the coordinator track");
    assert_eq!(door.at(&["tid"]).as_i64(), Some(route.rid as i64));
    let routing = of("routing_decision");
    assert_eq!(routing.at(&["pid"]).as_i64(), Some(0));
    assert_eq!(
        routing.at(&["args", "replica"]).as_i64(),
        Some(route.replica as i64),
        "the decision names the replica the span then renders under"
    );
    assert_eq!(
        routing.at(&["args", "candidates"]).as_arr().unwrap().len(),
        2,
        "the scored candidate set survives into the rendered args"
    );
    for phase in ["queued", "prefill", "decode"] {
        let ev = of(phase);
        assert_eq!(
            ev.at(&["pid"]).as_i64(),
            Some(route.replica as i64 + 1),
            "{phase} renders on the chosen replica's track"
        );
        assert_eq!(ev.at(&["tid"]).as_i64(), Some(route.rid as i64));
    }
    // process-name metadata labels both sides for Perfetto
    let procs: Vec<&str> = events
        .iter()
        .filter(|e| e.at(&["name"]).as_str() == Some("process_name"))
        .filter_map(|e| e.at(&["args", "name"]).as_str())
        .collect();
    assert!(procs.contains(&"coordinator"), "process names: {procs:?}");
    assert!(
        procs.contains(&format!("replica {}", route.replica).as_str()),
        "process names: {procs:?}"
    );
}
