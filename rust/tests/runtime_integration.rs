//! Integration tests over the tiny artifacts: PJRT compile + execute,
//! KV-cache chaining, and the end-to-end ExpertWeave≡merged equivalence
//! (the Table-3 mechanism) through the real runtime.
//!
//! Requires `make artifacts` (artifacts/tiny). All tests share one process
//! (single PJRT client requirement) via serialized sub-tests.

use expertweave::adapters::format::Adapter;
use expertweave::adapters::generator::{paper_adapter_profiles, synth_adapter};
use expertweave::adapters::registry::AdapterRegistry;
use expertweave::memsim::DeviceMemory;
use expertweave::model::ModelConfig;
use expertweave::runtime::{ArtifactSet, Runtime, StepInputs, Variant};
use expertweave::vmm::page_pool::PagePool;
use expertweave::weights::{
    BaseOnlyParams, BaseWeights, MergedParams, StoreMode, StoreParams, WeightStore,
};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

fn artifacts_dir() -> Option<PathBuf> {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    d.join("meta.json").exists().then_some(d)
}

fn adapter_for(cfg: &ModelConfig, name: &'static str, seed: u64) -> Adapter {
    let mut p = paper_adapter_profiles()[0].clone();
    p.name = name;
    p.max_experts = cfg.e_max;
    p.avg_experts = cfg.e_max as f64; // dense: every layer fine-tunes E_max
    synth_adapter(&p, cfg.layers, cfg.num_experts, cfg.hidden, cfg.expert_inter, seed)
}

/// A simple single-sequence prefill batch over the first `n` tokens.
fn prefill_batch(cfg: &ModelConfig, bucket: usize, out_rows: usize, toks: &[i32], aid: i32) -> StepInputs {
    let n = toks.len();
    assert!(n <= bucket);
    let mut b = StepInputs::blank(cfg, bucket, out_rows);
    for (i, &t) in toks.iter().enumerate() {
        b.token_ids[i] = t;
        b.positions[i] = i as i32;
        b.seg_ids[i] = 0;
        b.slot_idx[i] = i as i32;
        b.cache_seg[i] = 0;
        b.cache_pos[i] = i as i32;
        b.aid[i] = aid;
    }
    for r in b.out_rows.iter_mut() {
        *r = (n - 1) as i32;
    }
    b
}

#[test]
fn runtime_end_to_end() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: artifacts/tiny missing (run `make artifacts`)");
        return;
    };
    let set = ArtifactSet::load(&dir).unwrap();
    let cfg = set.config.clone();
    let base = BaseWeights::generate(&cfg, 11);

    // --- weave runtime over a virtual weight store with one adapter ----
    let pool = Arc::new(Mutex::new(PagePool::new(64 << 10, 1 << 14).unwrap()));
    let device = DeviceMemory::shared(usize::MAX / 2);
    let mut store = WeightStore::new(&cfg, StoreMode::Virtual, pool, device).unwrap();
    store.load_base(&base).unwrap();
    let mut registry = AdapterRegistry::new(&cfg);
    let ad = adapter_for(&cfg, "math", 3);
    registry.load(&mut store, &ad).unwrap();

    let mut weave = Runtime::new(&set, Variant::Weave).unwrap();
    {
        let mut src = StoreParams::new(&base, &store);
        weave.upload_params(&mut src, 1).unwrap();
    }
    weave
        .upload_expert_maps(registry.maps().as_slice(), registry.maps_version())
        .unwrap();

    let bucket = *weave.buckets().last().unwrap(); // widest batch: the
    // router reliably hits fine-tuned experts (tiny M, top-2)
    let out_rows = weave.out_rows(bucket).unwrap();
    let toks: Vec<i32> = (1..=bucket as i32).collect();

    // 1) logits well-formed
    let b = prefill_batch(&cfg, bucket, out_rows, &toks, -1);
    let out = weave.step(bucket, &b).unwrap();
    assert_eq!(out.logits.len(), out_rows * cfg.vocab);
    assert!(out.logits.iter().all(|x| x.is_finite()), "non-finite logits");

    // 2) KV persistence: decoding after prefill differs from decoding on
    // an empty cache
    weave.reset_kv();
    let _ = weave.step(bucket, &b).unwrap(); // prefill fills slots 0..bucket
    let mut dec = StepInputs::blank(&cfg, bucket, out_rows);
    dec.token_ids[0] = 7;
    dec.positions[0] = bucket as i32;
    dec.seg_ids[0] = 0;
    dec.slot_idx[0] = bucket as i32 % cfg.kv_cap as i32;
    for i in 0..bucket.min(cfg.kv_cap) {
        dec.cache_seg[i] = 0;
        dec.cache_pos[i] = i as i32;
    }
    dec.cache_seg[bucket % cfg.kv_cap] = 0;
    dec.cache_pos[bucket % cfg.kv_cap] = bucket as i32;
    let with_ctx = weave.step(bucket, &dec).unwrap();
    weave.reset_kv();
    let without_ctx = weave.step(bucket, &dec).unwrap();
    assert_ne!(with_ctx.logits, without_ctx.logits, "KV cache must persist");

    // 3) ExpertWeave == merged model, exactly (Table 3 mechanism):
    // serve the adapter through rerouting, compare with a base-variant
    // runtime holding offline-merged weights.
    let mut merged_rt = Runtime::new(&set, Variant::Base).unwrap();
    {
        let mut src = MergedParams::new(&cfg, &base, &ad);
        merged_rt.upload_params(&mut src, 1).unwrap();
    }
    let aid = registry.aid_of("math").unwrap();
    let bw = prefill_batch(&cfg, bucket, out_rows, &toks, aid);
    weave.reset_kv();
    let lw = weave.step(bucket, &bw).unwrap();
    let bm = prefill_batch(&cfg, bucket, out_rows, &toks, -1);
    let lm = merged_rt.step(bucket, &bm).unwrap();
    let max_diff = lw
        .logits
        .iter()
        .zip(&lm.logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 5e-4, "weave vs merged max diff {max_diff}");

    // 4) base tokens through the weave runtime == pure base model
    let mut base_rt = Runtime::new(&set, Variant::Base).unwrap();
    {
        let mut src = BaseOnlyParams { base: &base };
        base_rt.upload_params(&mut src, 1).unwrap();
    }
    weave.reset_kv();
    let lb_w = weave.step(bucket, &bm).unwrap(); // aid = -1 everywhere
    let lb = base_rt.step(bucket, &bm).unwrap();
    let max_diff = lb_w
        .logits
        .iter()
        .zip(&lb.logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 5e-4, "weave(base tokens) vs base max diff {max_diff}");
    // and the adapter path must actually differ from base
    assert_ne!(lw.logits, lb.logits, "adapter must change outputs");

    // 5) mixed batch: adapter tokens and base tokens interleaved in one
    // step give the same logits as the two homogeneous runs
    let half = bucket / 2;
    if half >= 1 {
        let mut mixed = StepInputs::blank(&cfg, bucket, out_rows);
        for i in 0..half {
            // seq 0: adapter tokens; seq 1: base tokens
            mixed.token_ids[i] = toks[i];
            mixed.positions[i] = i as i32;
            mixed.seg_ids[i] = 0;
            mixed.slot_idx[i] = i as i32;
            mixed.aid[i] = aid;
            let j = half + i;
            mixed.token_ids[j] = toks[i];
            mixed.positions[j] = i as i32;
            mixed.seg_ids[j] = 1;
            mixed.slot_idx[j] = j as i32;
            mixed.aid[j] = -1;
        }
        for i in 0..bucket {
            mixed.cache_seg[i] = if i < half { 0 } else { 1 };
            mixed.cache_pos[i] = (i % half) as i32;
        }
        mixed.out_rows[0] = (half - 1) as i32; // adapter seq last token
        if out_rows > 1 {
            mixed.out_rows[1] = (bucket - 1) as i32; // base seq last token
        }
        weave.reset_kv();
        let lmix = weave.step(bucket, &mixed).unwrap();

        // homogeneous reference runs over `half` tokens
        weave.reset_kv();
        let ra = weave
            .step(bucket, &prefill_batch(&cfg, bucket, out_rows, &toks[..half], aid))
            .unwrap();
        weave.reset_kv();
        let rb = weave
            .step(bucket, &prefill_batch(&cfg, bucket, out_rows, &toks[..half], -1))
            .unwrap();
        let d_a = lmix.logits[..cfg.vocab]
            .iter()
            .zip(&ra.logits[..cfg.vocab])
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(d_a < 5e-4, "mixed-batch adapter row diff {d_a}");
        if out_rows > 1 {
            let d_b = lmix.logits[cfg.vocab..2 * cfg.vocab]
                .iter()
                .zip(&rb.logits[..cfg.vocab])
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(d_b < 5e-4, "mixed-batch base row diff {d_b}");
        }
    }

    // 6) singleop variant gives identical results to the fused kernel
    let mut single = Runtime::new(&set, Variant::SingleOp).unwrap();
    {
        let mut src = StoreParams::new(&base, &store);
        single.upload_params(&mut src, 1).unwrap();
    }
    single
        .upload_expert_maps(registry.maps().as_slice(), registry.maps_version())
        .unwrap();
    let ls = single.step(bucket, &bw).unwrap();
    weave.reset_kv();
    let lw2 = weave.step(bucket, &bw).unwrap();
    let max_diff = ls
        .logits
        .iter()
        .zip(&lw2.logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 5e-4, "singleop vs fused max diff {max_diff}");
}
