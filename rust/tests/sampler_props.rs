//! Property suite for the production sampling surface ([`SamplingParams`]
//! / [`SamplerBank`]): randomized invariants over the filtered sampling
//! paths, plus engine-level stop-condition behaviour.
//!
//! Properties pinned here (the ISSUE 10 archetype centerpiece):
//! - the top-p support is exactly the *minimal* probability-sorted prefix
//!   whose mass reaches `p` — nothing outside it is ever sampled, and the
//!   boundary token completing the mass stays sampleable;
//! - top-k only ever returns one of the `k` largest logits;
//! - the repetition penalty strictly lowers a seen token's relative
//!   probability and leaves unseen tokens untouched;
//! - a `-inf` logit bias makes a token unsampleable under every mode;
//! - stop-sequence matching fires across a step boundary (the match
//!   cursor persists between engine steps), and `max_len` caps the total
//!   sequence length.

use expertweave::engine::{Engine, EngineOptions, RequestSpec};
use expertweave::model::ModelConfig;
use expertweave::runtime::{SimPerf, Variant};
use expertweave::sampler::{FinishReason, SamplerBank, SamplingParams};
use expertweave::util::prop;
use expertweave::weights::StoreMode;

/// The sampler's NaN-as-`-inf` ordering key, mirrored for references.
fn key(x: f32) -> f32 {
    if x.is_nan() {
        f32::NEG_INFINITY
    } else {
        x
    }
}

/// Candidate order the sampler uses: logit descending, index ascending.
fn ranked(logits: &[f32]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.sort_by(|&a, &b| key(logits[b]).total_cmp(&key(logits[a])).then(a.cmp(&b)));
    idx
}

fn sim_engine(seed: u64) -> Engine {
    Engine::sim_weave(
        &ModelConfig::sim_default(),
        SimPerf::instant(),
        &[],
        Variant::Weave,
        StoreMode::Virtual,
        EngineOptions { page_size: 64 << 10, seed, ..Default::default() },
    )
    .unwrap()
}

fn greedy_req(prompt: Vec<i32>, max_new: usize, sampling: SamplingParams) -> RequestSpec {
    RequestSpec { adapter: None, prompt, max_new_tokens: max_new, sampling }
}

#[test]
fn top_p_samples_only_from_minimal_prefix_mass() {
    prop::check(101, 30, |rng| {
        let n = 4 + rng.below(12) as usize;
        let logits: Vec<f32> = (0..n).map(|_| rng.f32() * 8.0 - 4.0).collect();
        let p = 0.2 + rng.f32() * 0.75;
        // reference support, mirroring the sampler's f32 math exactly:
        // rank candidates, accumulate probabilities until the mass
        // reaches p * total — that prefix is the only legal support
        let idx = ranked(&logits);
        let m = key(logits[idx[0]]);
        let probs: Vec<f32> = idx.iter().map(|&i| (key(logits[i]) - m).exp()).collect();
        let sum: f32 = probs.iter().sum();
        let target = p * sum;
        let mut cut = probs.len();
        let mut acc = 0.0f32;
        for (j, &q) in probs.iter().enumerate() {
            acc += q;
            if acc >= target {
                cut = j + 1;
                break;
            }
        }
        let support = &idx[..cut];

        let params = SamplingParams::top_p(p, 1.0);
        let mut bank = SamplerBank::new(1, n);
        for s in 0..64u64 {
            let slot = bank.acquire(rng.next_u64() ^ s, &[]);
            let mut row = logits.clone();
            let t = bank.sample_row(slot, &params, &mut row) as usize;
            assert!(
                support.contains(&t),
                "sampled {t} outside the top-{p} support {support:?} of {logits:?}"
            );
            bank.release(slot);
        }
    });
}

#[test]
fn top_p_prefix_is_minimal() {
    // probs 0.5 / 0.3 / 0.2 at T=1 with p = 0.75: the minimal prefix is
    // {0, 1} (0.5 < 0.75 <= 0.8). The boundary token that completes the
    // mass must stay sampleable; the token just past it must not be.
    let logits = [(0.5f32).ln(), (0.3f32).ln(), (0.2f32).ln()];
    let params = SamplingParams::top_p(0.75, 1.0);
    let mut bank = SamplerBank::new(1, 3);
    let mut boundary_seen = false;
    for s in 0..400u64 {
        let slot = bank.acquire(s, &[]);
        let mut row = logits;
        let t = bank.sample_row(slot, &params, &mut row);
        assert_ne!(t, 2, "token outside the minimal prefix must be unsampleable");
        boundary_seen = boundary_seen || t == 1;
        bank.release(slot);
    }
    assert!(boundary_seen, "the boundary token completing the mass is in the support");
}

#[test]
fn top_k_samples_only_the_k_largest() {
    prop::check(202, 30, |rng| {
        let n = 4 + rng.below(12) as usize;
        let logits: Vec<f32> = (0..n).map(|_| rng.f32() * 8.0 - 4.0).collect();
        let k = 1 + rng.below(n as u64) as usize;
        let top = &ranked(&logits)[..k];
        let params = SamplingParams::top_k(k, 0.7);
        let mut bank = SamplerBank::new(1, n);
        for s in 0..64u64 {
            let slot = bank.acquire(s, &[]);
            let mut row = logits.clone();
            let t = bank.sample_row(slot, &params, &mut row) as usize;
            assert!(top.contains(&t), "sampled {t} outside the top-{k}: {top:?}");
            bank.release(slot);
        }
    });
}

#[test]
fn repetition_penalty_strictly_lowers_seen_token_probability() {
    prop::check(303, 20, |rng| {
        let n = 6usize;
        // positive logits so the divide-by-penalty branch is operative
        let logits: Vec<f32> = (0..n).map(|_| 1.0 + rng.f32() * 2.0).collect();
        let seen = rng.below(n as u64) as i32;
        let plain = SamplingParams::temperature(1.0);
        let mut penalized = plain.clone();
        penalized.repetition_penalty = 2.0 + rng.f32();
        let mut bank = SamplerBank::new(1, n);

        // (i) the logit transform: the seen token is discounted in place,
        // every unseen token is untouched
        let slot = bank.acquire(0, &[seen]);
        let mut row = logits.clone();
        let _ = bank.sample_row(slot, &penalized, &mut row);
        assert!(row[seen as usize] < logits[seen as usize]);
        for (i, (&got, &want)) in row.iter().zip(logits.iter()).enumerate() {
            if i != seen as usize {
                assert_eq!(got, want, "unseen token {i} must be untouched");
            }
        }
        bank.release(slot);

        // (ii) empirically: the seen token is drawn strictly less often
        // (its logit at least halves, so the gap dwarfs sampling noise)
        let mut freq = |params: &SamplingParams, prompt: &[i32]| -> f64 {
            let draws = 8000u64;
            let mut hits = 0u64;
            for s in 0..draws {
                let slot = bank.acquire(s, prompt);
                let mut row = logits.clone();
                if bank.sample_row(slot, params, &mut row) == seen {
                    hits += 1;
                }
                bank.release(slot);
            }
            hits as f64 / draws as f64
        };
        let base = freq(&plain, &[]);
        let discounted = freq(&penalized, &[seen]);
        assert!(discounted < base, "penalized {discounted} !< baseline {base}");
    });
}

#[test]
fn neg_inf_logit_bias_is_never_sampled() {
    prop::check(404, 25, |rng| {
        let n = 4 + rng.below(8) as usize;
        let logits: Vec<f32> = (0..n).map(|_| rng.f32() * 6.0 - 3.0).collect();
        let banned = rng.below(n as u64) as i32;
        let mut variants = vec![
            SamplingParams::greedy(),
            SamplingParams::temperature(0.8),
            SamplingParams::top_k(2.max(n / 2), 1.0),
            SamplingParams::top_p(0.9, 1.0),
        ];
        for params in &mut variants {
            params.logit_bias = vec![(banned, f32::NEG_INFINITY)];
        }
        let mut bank = SamplerBank::new(1, n);
        for params in &variants {
            for s in 0..50u64 {
                let slot = bank.acquire(s, &[]);
                let mut row = logits.clone();
                assert_ne!(bank.sample_row(slot, params, &mut row), banned);
                bank.release(slot);
            }
        }
    });
}

#[test]
fn stop_sequence_match_straddles_step_boundary() {
    // learn the deterministic greedy stream, then replay with a stop
    // sequence spanning generated tokens 1..=2 — the engine emits one
    // token per decode step, so the match begins in one step and
    // completes in the next (the per-slot match cursor must persist)
    let mut probe = sim_engine(7);
    probe
        .submit(greedy_req(vec![1, 2, 3, 4], 6, SamplingParams::greedy()))
        .unwrap();
    let done = probe.run_to_completion().unwrap();
    let stream = done[0].output.clone();
    assert_eq!(stream.len(), 6);
    assert_eq!(done[0].finish, FinishReason::Length);

    let stop = vec![stream[1], stream[2]];
    let mut sampling = SamplingParams::greedy();
    sampling.stop_sequences = vec![stop.clone()];
    let mut e = sim_engine(7);
    e.submit(greedy_req(vec![1, 2, 3, 4], 6, sampling)).unwrap();
    let done = e.run_to_completion().unwrap();
    let out = &done[0].output;
    assert_eq!(done[0].finish, FinishReason::Stop, "must finish on the stop match");
    assert!(out.len() < stream.len(), "the stop halts generation early: {out:?}");
    assert_eq!(out[out.len() - 2..], stop[..], "output ends with the stop sequence");
}

#[test]
fn stop_token_id_finishes_with_stop_reason() {
    let mut probe = sim_engine(9);
    probe
        .submit(greedy_req(vec![5, 6, 7], 4, SamplingParams::greedy()))
        .unwrap();
    let stream = probe.run_to_completion().unwrap()[0].output.clone();

    let mut sampling = SamplingParams::greedy();
    sampling.stop_token_ids = vec![stream[1]];
    let mut e = sim_engine(9);
    e.submit(greedy_req(vec![5, 6, 7], 4, sampling)).unwrap();
    let done = e.run_to_completion().unwrap();
    assert_eq!(done[0].finish, FinishReason::Stop);
    assert_eq!(*done[0].output.last().unwrap(), stream[1]);
    assert!(done[0].output.len() <= 2);
}

#[test]
fn max_len_caps_total_sequence_length() {
    let mut sampling = SamplingParams::greedy();
    sampling.max_len = 6; // prompt is 4 tokens -> at most 2 generated
    let mut e = sim_engine(3);
    e.submit(greedy_req(vec![1, 2, 3, 4], 100, sampling)).unwrap();
    let done = e.run_to_completion().unwrap();
    assert_eq!(done[0].output.len(), 2);
    assert_eq!(done[0].finish, FinishReason::Length);
}

/// A `max_len` already exhausted by the prompt finishes at the door:
/// reason `length`, *empty* output (no forced token), no KV held — and
/// the completion still surfaces through `run_to_completion`.
#[test]
fn max_len_at_or_below_prompt_finishes_immediately_with_empty_output() {
    for cap in [4usize, 2, 1] {
        let mut sampling = SamplingParams::greedy();
        sampling.max_len = cap; // prompt is 4 tokens: zero token budget
        let mut e = sim_engine(3);
        let free = e.kv_free_slots();
        e.submit(greedy_req(vec![1, 2, 3, 4], 100, sampling)).unwrap();
        assert_eq!(e.kv_free_slots(), free, "door completion must not touch KV");
        let done = e.run_to_completion().unwrap();
        assert_eq!(done.len(), 1);
        assert!(done[0].output.is_empty(), "no token may be generated (cap {cap})");
        assert_eq!(done[0].finish, FinishReason::Length);
        assert_eq!(done[0].record.output_tokens, 0);
        let report = e.report();
        assert_eq!(report.requests, 1, "booked as a completion, not a rejection");
        assert_eq!(report.rejected, 0);
    }
}
