//! Determinism differential tests for seeded sampling: a request's token
//! stream is a pure function of (engine seed, per-request sampler seed,
//! its own prompt) — byte-identical across
//!
//! (a) the sim backend's greedy fast path vs `sim_full_logits` mode
//!     (which materializes every logits row even for all-greedy batches),
//! (c) two runs where slot/batch assignment order differs (the per-slot
//!     PRNG streams are keyed by the request seed, never by slot number
//!     or batch composition).
//!
//! (b) — solo engine vs a fleet replica — lives in `fleet_online.rs`
//! next to the coordinator plumbing it exercises.

use expertweave::engine::{Engine, EngineOptions, RequestSpec};
use expertweave::model::ModelConfig;
use expertweave::runtime::{SimPerf, Variant};
use expertweave::sampler::SamplingParams;
use expertweave::weights::StoreMode;

fn engine_with(seed: u64, full_logits: bool) -> Engine {
    let mut cfg = ModelConfig::sim_default();
    cfg.kv_cap = 4096;
    Engine::sim_weave(
        &cfg,
        SimPerf::instant(),
        &[],
        Variant::Weave,
        StoreMode::Virtual,
        EngineOptions {
            page_size: 64 << 10,
            seed,
            sim_full_logits: full_logits,
            ..Default::default()
        },
    )
    .unwrap()
}

/// (a) Mixed greedy + sampled batch: the greedy fast path (no logits
/// materialized for all-greedy steps) and the full-logits path must emit
/// byte-identical streams for every request.
#[test]
fn mixed_batch_identical_across_fast_path_and_full_logits() {
    let run = |full: bool| -> Vec<(u64, Vec<i32>)> {
        let mut e = engine_with(11, full);
        for i in 0..6usize {
            let sampling = match i % 3 {
                0 => SamplingParams::greedy(),
                1 => SamplingParams::temperature(0.9).with_seed(500 + i as u64),
                _ => SamplingParams::top_p(0.85, 0.9).with_seed(500 + i as u64),
            };
            e.submit(RequestSpec {
                adapter: None,
                prompt: (1..=4 + i as i32).collect(),
                max_new_tokens: 10,
                sampling,
            })
            .unwrap();
        }
        let mut done: Vec<(u64, Vec<i32>)> = e
            .run_to_completion()
            .unwrap()
            .into_iter()
            .map(|c| (c.id, c.output))
            .collect();
        done.sort_by_key(|(id, _)| *id);
        done
    };
    let fast = run(false);
    let full = run(true);
    assert_eq!(fast.len(), 6);
    assert_eq!(fast, full, "fast-path and full-logits streams must be byte-identical");
}

/// (a) corollary: an all-greedy batch takes the O(1) fast path outright;
/// forcing full logits + argmax must reproduce the exact same streams.
#[test]
fn all_greedy_batch_identical_across_fast_path_and_full_logits() {
    let run = |full: bool| -> Vec<(u64, Vec<i32>)> {
        let mut e = engine_with(5, full);
        for i in 0..4i32 {
            e.submit(RequestSpec {
                adapter: None,
                prompt: (1..=3 + i).collect(),
                max_new_tokens: 8,
                sampling: SamplingParams::greedy(),
            })
            .unwrap();
        }
        let mut done: Vec<(u64, Vec<i32>)> = e
            .run_to_completion()
            .unwrap()
            .into_iter()
            .map(|c| (c.id, c.output))
            .collect();
        done.sort_by_key(|(id, _)| *id);
        done
    };
    assert_eq!(run(false), run(true));
}

/// (c) Slot-assignment invariance: the same seeded request produces the
/// same stream whether it is admitted first into a fresh engine or
/// squeezed in after a pack of fillers has been running for several
/// steps (different sampler slot, different row index, different batch
/// mix around it).
#[test]
fn seeded_stream_invariant_under_slot_assignment_order() {
    let probe = || RequestSpec {
        adapter: None,
        prompt: vec![9, 8, 7, 6, 5],
        max_new_tokens: 12,
        sampling: SamplingParams::top_p(0.9, 0.8).with_seed(0xBEEF),
    };
    let filler = |i: usize| RequestSpec {
        adapter: None,
        prompt: (1..=2 + i as i32).collect(),
        max_new_tokens: 6 + i,
        sampling: if i % 2 == 0 {
            SamplingParams::greedy()
        } else {
            SamplingParams::temperature(1.1).with_seed(i as u64)
        },
    };
    let output_of = |done: Vec<expertweave::engine::Completion>, id: u64| -> Vec<i32> {
        done.into_iter()
            .find(|c| c.id == id)
            .expect("probe must complete")
            .output
    };

    // run 1: probe admitted first, fillers behind it
    let mut e1 = engine_with(21, false);
    let id1 = e1.submit(probe()).unwrap();
    for i in 0..5 {
        e1.submit(filler(i)).unwrap();
    }
    let out1 = output_of(e1.run_to_completion().unwrap(), id1);

    // run 2: fillers admitted first and stepped for a while (some have
    // already finished and recycled their sampler slots), then the probe
    let mut e2 = engine_with(21, false);
    for i in 0..5 {
        e2.submit(filler(i)).unwrap();
    }
    for _ in 0..4 {
        e2.step().unwrap();
    }
    let id2 = e2.submit(probe()).unwrap();
    let out2 = output_of(e2.run_to_completion().unwrap(), id2);

    assert_eq!(out1.len(), 12, "probe must run to its token budget");
    assert_eq!(
        out1, out2,
        "seeded stream must not depend on slot assignment or batch mix"
    );
}
