//! Differential property test for the workspace-based batch builder:
//! across randomized submit / cancel / expire / build interleavings, a
//! `build_batch` into a *reused* [`StepWorkspace`] must be byte-identical
//! to the same build performed on a cloned scheduler + KV cache into a
//! *fresh* workspace (the fresh-allocation reference) — i.e. no stale
//! state from previous batches may ever leak through the reused buffers.
//!
//! The persistent `cache_seg` / `cache_pos` arrays are cumulative, so
//! they are checked against an independent first-principles
//! reconstruction from the per-sequence KV slot lists instead.

use expertweave::kvcache::PagedKvCache;
use expertweave::sampler::SamplingParams;
use expertweave::scheduler::{seg_of, SchedConfig, Scheduler, SeqState, StepWorkspace};
use expertweave::util::prop;
use std::time::{Duration, Instant};

/// Rebuild the device-visible slot metadata from scratch: every running
/// sequence's slots carry its seg id and positions 0..len; everything
/// else is cleared (-1 / 0). Block size 1 with sharing off keeps the
/// paged cache at flat private-slot semantics, so slot == block id.
fn reconstruct_cache(s: &Scheduler, kv: &PagedKvCache, cap: usize) -> (Vec<i32>, Vec<i32>) {
    let mut seg = vec![-1; cap];
    let mut pos = vec![0; cap];
    for q in s.running() {
        if let Some(slots) = kv.blocks_of(q.id) {
            for (p, &sl) in slots.iter().enumerate() {
                seg[sl as usize] = seg_of(q.id);
                pos[sl as usize] = p as i32;
            }
        }
    }
    (seg, pos)
}

#[test]
fn workspace_build_matches_fresh_allocation_reference() {
    prop::check(4242, 40, |rng| {
        let max_seqs = 1 + rng.below(5) as usize;
        let cfg = SchedConfig {
            max_seqs,
            abi_max_seqs: max_seqs,
            chunk: 1 + rng.below(10) as usize,
            buckets: vec![4, 16, 64],
            kv_cap: 128,
        };
        let mut s = Scheduler::new(cfg.clone());
        let mut kv = PagedKvCache::new(cfg.kv_cap, 1, false);
        let mut ws = StepWorkspace::new(&cfg, 16);
        let mut next_id = 0u64;
        let mut live: Vec<u64> = Vec::new();
        let far_future = Instant::now() + Duration::from_secs(3600);

        for _ in 0..40 {
            match rng.below(8) {
                0 | 1 | 2 => {
                    next_id += 1;
                    let mut seq = SeqState::new(
                        next_id,
                        if rng.below(2) == 0 { -1 } else { rng.below(4) as i32 },
                        None,
                        (0..(1 + rng.below(24) as i32)).collect(),
                        1 + rng.below(4) as usize,
                        if rng.below(3) == 0 {
                            SamplingParams::temperature(0.8)
                        } else {
                            SamplingParams::greedy()
                        },
                    );
                    // some sequences carry deadlines; a third of those
                    // are already expired and must vanish via expire
                    seq.deadline = match rng.below(6) {
                        0 => Some(Instant::now()),
                        1 | 2 => Some(far_future),
                        _ => None,
                    };
                    live.push(seq.id);
                    s.submit(seq);
                }
                3 if !live.is_empty() => {
                    let i = rng.below(live.len() as u64) as usize;
                    let id = live.swap_remove(i);
                    s.cancel(id, &mut kv, &mut ws);
                }
                4 => {
                    for gone in s.expire_deadlines(Instant::now(), &mut kv, &mut ws) {
                        live.retain(|&x| x != gone.id);
                    }
                }
                _ => {
                    // differential build: identical state, fresh buffers
                    let mut s_ref = s.clone();
                    let mut kv_ref = kv.clone();
                    let mut ws_ref = StepWorkspace::new(&cfg, 16);
                    let b_ref = s_ref.build_batch(&mut kv_ref, &mut ws_ref).unwrap();
                    let b = s.build_batch(&mut kv, &mut ws).unwrap();
                    assert_eq!(b, b_ref, "batch summaries must agree");
                    if b.is_some() {
                        assert_eq!(ws.inputs.token_ids, ws_ref.inputs.token_ids);
                        assert_eq!(ws.inputs.positions, ws_ref.inputs.positions);
                        assert_eq!(ws.inputs.seg_ids, ws_ref.inputs.seg_ids);
                        assert_eq!(ws.inputs.slot_idx, ws_ref.inputs.slot_idx);
                        assert_eq!(ws.inputs.aid, ws_ref.inputs.aid);
                        assert_eq!(ws.inputs.out_rows, ws_ref.inputs.out_rows);
                        // sampler-slot numbers are bank-assignment order,
                        // which legitimately differs between the reused
                        // bank and a fresh one (and must not matter —
                        // see the sampling determinism tests); compare
                        // everything else
                        let row_key = |rows: &[expertweave::scheduler::OutRow]| {
                            rows.iter()
                                .map(|r| (r.row, r.seq, r.aid, r.needs_logits))
                                .collect::<Vec<_>>()
                        };
                        assert_eq!(row_key(&ws.rows), row_key(&ws_ref.rows));
                    }
                    // persistent cache metadata == independent rebuild
                    let (seg, pos) = reconstruct_cache(&s, &kv, cfg.kv_cap);
                    assert_eq!(ws.inputs.cache_seg, seg, "cache_seg drifted");
                    assert_eq!(ws.inputs.cache_pos, pos, "cache_pos drifted");

                    for r in &ws.rows {
                        s.push_token(r.seq, 7).unwrap();
                    }
                    for done in s.reap(&mut kv, &mut ws) {
                        live.retain(|&x| x != done.id);
                    }
                }
            }
        }

        // drain completely; the metadata must end fully cleared
        for _ in 0..500 {
            s.expire_deadlines(Instant::now(), &mut kv, &mut ws);
            match s.build_batch(&mut kv, &mut ws).unwrap() {
                Some(_) => {
                    for r in &ws.rows {
                        s.push_token(r.seq, 7).unwrap();
                    }
                    s.reap(&mut kv, &mut ws);
                }
                None => break,
            }
        }
        assert!(s.is_idle(), "scheduler must drain");
        assert_eq!(kv.used_slots(), 0);
        assert!(ws.inputs.cache_seg.iter().all(|&x| x == -1));
        assert!(ws.inputs.cache_pos.iter().all(|&x| x == 0));
        assert_eq!(ws.samplers.in_use(), 0, "drained scheduler must free every sampler slot");
    });
}
