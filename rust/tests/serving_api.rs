//! Serving-API integration over the sim backend: token streaming,
//! cancellation (KV reclaim), deadlines (no batch slot for expired
//! requests), drain semantics, typed submit errors — and the NDJSON
//! TCP frontend end to end (submit, stream, cancel, drain).

use expertweave::adapters::generator::synth_fleet_adapters;
use expertweave::engine::{Completion, Engine, EngineOptions};
use expertweave::model::ModelConfig;
use expertweave::runtime::{SimPerf, Variant};
use expertweave::sampler::SamplingParams;
use expertweave::serving::frontend::{NdjsonClient, NdjsonServer};
use expertweave::serving::{
    AbortReason, RequestHandle, ServeRequest, ServingBackend, SubmitError, TokenEvent,
};
use expertweave::weights::StoreMode;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

fn sim_engine(opts: EngineOptions) -> (Engine, Vec<String>) {
    let cfg = ModelConfig::sim_default();
    let adapters = synth_fleet_adapters(&cfg, 2, 42);
    let names = adapters.iter().map(|a| a.name.clone()).collect();
    let engine = Engine::sim_weave(
        &cfg,
        SimPerf::fast(),
        &adapters,
        Variant::Weave,
        StoreMode::Virtual,
        EngineOptions { page_size: 64 << 10, ..opts },
    )
    .unwrap();
    (engine, names)
}

fn req(adapter: Option<&str>, prompt_len: usize, max_new: usize) -> ServeRequest {
    ServeRequest {
        adapter: adapter.map(str::to_string),
        prompt: (1..=prompt_len as i32).collect(),
        max_new_tokens: max_new,
        sampling: SamplingParams::greedy(),
        deadline: None,
        trace: None,
    }
}

#[test]
fn stream_orders_first_tokens_done() {
    let (mut e, names) = sim_engine(EngineOptions::default());
    let h = e.submit_request(req(Some(&names[0]), 6, 4)).unwrap();
    while ServingBackend::pump(&mut e).unwrap() {}
    let evs = h.drain_events();
    assert_eq!(evs.len(), 5, "First + 3 Token + Done");
    assert!(matches!(evs[0], TokenEvent::First { .. }));
    for ev in &evs[1..4] {
        assert!(matches!(ev, TokenEvent::Token { .. }));
    }
    let TokenEvent::Done { completion, .. } = &evs[4] else {
        panic!("last event must be Done: {:?}", evs[4]);
    };
    // the streamed tokens ARE the completion's output, in order
    let streamed: Vec<i32> = evs[..4]
        .iter()
        .map(|ev| match ev {
            TokenEvent::First { token, .. } | TokenEvent::Token { token, .. } => *token,
            other => panic!("unexpected {other:?}"),
        })
        .collect();
    assert_eq!(streamed, completion.output);
    assert_eq!(completion.record.output_tokens, 4);
}

#[test]
fn cancel_mid_decode_frees_kv_and_marks_aborted() {
    let (mut e, names) = sim_engine(EngineOptions::default());
    let kv_cap = e.config().kv_cap;
    let h = e.submit_request(req(Some(&names[0]), 8, 512)).unwrap();
    // pump until the request is decoding (First token seen)
    let mut first_seen = false;
    for _ in 0..64 {
        ServingBackend::pump(&mut e).unwrap();
        if h.drain_events().iter().any(|ev| matches!(ev, TokenEvent::First { .. })) {
            first_seen = true;
            break;
        }
    }
    assert!(first_seen, "request never started decoding");
    assert!(e.kv_free_slots() < kv_cap, "mid-decode: KV slots held");

    assert!(ServingBackend::cancel(&mut e, h.id), "cancel must find it");
    assert_eq!(e.kv_free_slots(), kv_cap, "cancel frees KV immediately");
    assert!(!ServingBackend::has_work(&e));
    let evs = h.drain_events();
    assert!(
        matches!(
            evs.last(),
            Some(TokenEvent::Aborted { reason: AbortReason::Cancelled, .. })
        ),
        "stream must end Aborted(Cancelled): {evs:?}"
    );
    assert!(!ServingBackend::cancel(&mut e, h.id), "idempotent");
    let report = e.report();
    assert_eq!(report.aborted, 1);
    assert_eq!(report.deadline_missed, 0);
    assert_eq!(report.requests, 0, "aborted request is not a completion");
}

#[test]
fn expired_deadline_never_occupies_a_batch_slot() {
    let (mut e, names) = sim_engine(EngineOptions::default());
    let mut dead = req(Some(&names[0]), 8, 8);
    dead.deadline = Some(Duration::ZERO); // expired before the first pump
    let h_dead = e.submit_request(dead).unwrap();
    let h_live = e.submit_request(req(Some(&names[1]), 8, 2)).unwrap();
    while ServingBackend::pump(&mut e).unwrap() {}

    let evs = h_dead.drain_events();
    assert_eq!(evs.len(), 1, "no token may precede the abort: {evs:?}");
    assert!(matches!(
        evs[0],
        TokenEvent::Aborted { reason: AbortReason::DeadlineExceeded, .. }
    ));
    assert!(h_live
        .drain_events()
        .iter()
        .any(|ev| matches!(ev, TokenEvent::Done { .. })));
    let report = e.report();
    assert_eq!(report.deadline_missed, 1);
    assert_eq!(report.aborted, 1);
    assert_eq!(report.requests, 1, "only the live request completed");
}

#[test]
fn drain_completes_in_flight_then_rejects_new_submits() {
    let (mut e, names) = sim_engine(EngineOptions::default());
    let h1 = e.submit_request(req(Some(&names[0]), 6, 3)).unwrap();
    let h2 = e.submit_request(req(None, 4, 2)).unwrap();
    ServingBackend::drain(&mut e).unwrap();
    for h in [&h1, &h2] {
        assert!(
            h.drain_events().iter().any(|ev| matches!(ev, TokenEvent::Done { .. })),
            "drain must complete in-flight work"
        );
    }
    assert!(!ServingBackend::has_work(&e));
    match ServingBackend::submit(&mut e, req(None, 4, 1)) {
        Err(SubmitError::ShuttingDown) => {}
        other => panic!("post-drain submit must be ShuttingDown, got {other:?}"),
    }
    let report = e.report();
    assert_eq!(report.requests, 2);
    assert_eq!(report.rejected, 1, "ShuttingDown rejections are counted");
}

#[test]
fn deadline_aware_admission_rejects_at_submit() {
    // max_seqs 1 so a queue actually builds behind the running request
    let (mut e, names) = sim_engine(EngineOptions { max_seqs: 1, ..Default::default() });

    // before any step, the EWMA is unknown: even a tiny deadline must be
    // admitted (it can still expire in the queue, but not at the door)
    let mut tiny = req(None, 4, 1);
    tiny.deadline = Some(Duration::from_nanos(1));
    let h_tiny = e.submit_request(tiny).unwrap();
    while ServingBackend::pump(&mut e).unwrap() {}
    assert!(matches!(
        h_tiny.drain_events().last(),
        Some(TokenEvent::Aborted { reason: AbortReason::DeadlineExceeded, .. })
    ));

    // prime the EWMA with a completed request
    let _h = e.submit_request(req(Some(&names[0]), 6, 3)).unwrap();
    while ServingBackend::pump(&mut e).unwrap() {}

    // occupy the engine and put one request in the waiting queue
    let _busy = e.submit_request(req(None, 4, 200)).unwrap();
    let _queued = e.submit_request(req(None, 4, 4)).unwrap();
    ServingBackend::pump(&mut e).unwrap();

    // expected wait = EWMA step time × queue depth >> 1ns: reject at the
    // door instead of letting it rot in the queue
    let mut tight = req(None, 4, 2);
    tight.deadline = Some(Duration::from_nanos(1));
    match e.submit_request(tight) {
        Err(SubmitError::DeadlineUnmeetable) => {}
        other => panic!("expected DeadlineUnmeetable, got {other:?}"),
    }

    // a generous deadline sails through the same queue
    let mut ok = req(None, 4, 2);
    ok.deadline = Some(Duration::from_secs(600));
    let h_ok = e.submit_request(ok).unwrap();
    while ServingBackend::pump(&mut e).unwrap() {}
    assert!(h_ok
        .drain_events()
        .iter()
        .any(|ev| matches!(ev, TokenEvent::Done { .. })));

    let report = e.report();
    assert_eq!(report.rejected, 1, "the unmeetable deadline was booked");
    assert_eq!(report.deadline_missed, 1, "only the pre-EWMA tiny deadline expired");
}

/// The split step-time estimator: a heavy-prefill burst inflates only
/// the prefill EWMA, so a borderline *decode* deadline is still admitted
/// where the old unified EWMA would have over-rejected it until the
/// estimate re-converged (ROADMAP "Deadline admission", PR 4 caveat).
#[test]
fn prefill_burst_does_not_inflate_decode_deadline_admission() {
    let cfg = ModelConfig::sim_default();
    // the sim's latency is bucket-shaped (step_base + per_token x
    // bucket): decode steps land in the 16-token bucket (~17 ms here),
    // while a 256-token prefill chunk lands in the 256 bucket (~257
    // ms). The per-token cost is a sleep, so the burst's inflation of
    // the prefill estimate has a deterministic lower bound even on
    // loaded CI runners.
    let perf = SimPerf {
        step_base: Duration::from_millis(1),
        per_token: Duration::from_millis(1),
        adapter_swap: Duration::from_millis(2),
    };
    let mut e = Engine::sim_weave(
        &cfg,
        perf,
        &[],
        Variant::Weave,
        StoreMode::Virtual,
        EngineOptions {
            page_size: 64 << 10,
            chunk: 256,
            max_seqs: 1,
            ..Default::default()
        },
    )
    .unwrap();

    // 1) prime both estimates with a short request (1 prefill step, then
    // pure decode steps)
    let h = e.submit_request(req(None, 2, 6)).unwrap();
    while ServingBackend::pump(&mut e).unwrap() {}
    assert!(has_done_event(&h.drain_events()));
    let primed = e.step_ewma();
    assert!(primed.decode > 0.0 && primed.prefill > 0.0);

    // 2) heavy-prefill burst: a 768-token prompt chunked at 256 runs
    // three >= 257 ms prefill steps, pushing the prefill EWMA past
    // 80 ms (0.8/0.2 smoothing from ~17 ms: 65 -> 103 -> 134 ms) while
    // the decode estimate stays ~17 ms
    let _busy = e.submit_request(req(None, 768, 50)).unwrap();
    for _ in 0..3 {
        ServingBackend::pump(&mut e).unwrap();
    }
    let ewma = e.step_ewma();
    assert!(
        ewma.prefill > ewma.decode * 2.0,
        "the burst must inflate only the prefill estimate: {ewma:?}"
    );
    assert!(ewma.prefill > 0.080, "3 chunked steps of >= 257 ms each: {ewma:?}");
    assert!(ewma.decode < 0.080, "decode estimate untouched by the burst: {ewma:?}");

    // 3) with one request waiting behind the busy engine, an 80 ms
    // deadline is borderline: above decode-EWMA x depth (admit), below
    // prefill-EWMA x depth (a unified estimate would have rejected)
    let _queued = e.submit_request(req(None, 2, 2)).unwrap();
    let mut borderline = req(None, 2, 2);
    borderline.deadline = Some(Duration::from_millis(80));
    let _admitted = e
        .submit_request(borderline)
        .expect("split estimator must admit a decode-borderline deadline");
    // drain everything; the borderline request may legitimately expire
    // later (admission is about the door, not a completion guarantee)
    while ServingBackend::pump(&mut e).unwrap() {}
    let report = e.report();
    assert_eq!(report.rejected, 0, "no deadline rejection at the door");
}

fn has_done_event(evs: &[TokenEvent]) -> bool {
    evs.iter().any(|ev| matches!(ev, TokenEvent::Done { .. }))
}

#[test]
fn typed_submit_errors_and_internal_rejection_accounting() {
    let (mut e, _names) = sim_engine(EngineOptions { queue_cap: 1, ..Default::default() });
    match e.submit_request(req(Some("ghost"), 4, 1)) {
        Err(SubmitError::UnknownAdapter(n)) => assert_eq!(n, "ghost"),
        other => panic!("expected UnknownAdapter, got {other:?}"),
    }
    match e.submit_request(ServeRequest { prompt: vec![], ..req(None, 1, 1) }) {
        Err(SubmitError::Invalid(_)) => {}
        other => panic!("expected Invalid, got {other:?}"),
    }
    let kv_cap = e.config().kv_cap;
    match e.submit_request(req(None, 8, kv_cap)) {
        Err(SubmitError::Invalid(_)) => {}
        other => panic!("expected Invalid (KV overflow), got {other:?}"),
    }
    // queue_cap = 1: the second queued submit is QueueFull
    let _h = e.submit_request(req(None, 4, 1)).unwrap();
    match e.submit_request(req(None, 4, 1)) {
        Err(SubmitError::QueueFull) => {}
        other => panic!("expected QueueFull, got {other:?}"),
    }
    while ServingBackend::pump(&mut e).unwrap() {}
    // every rejection above was booked by the engine itself
    let report = e.report();
    assert_eq!(report.rejected, 4);
    assert_eq!(report.requests, 1);
}

// ---------------------------------------------------------------------
// NDJSON TCP frontend, end to end on the sim backend.
// ---------------------------------------------------------------------

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(30)))
            .unwrap();
        let writer = stream.try_clone().unwrap();
        Client { reader: BufReader::new(stream), writer }
    }

    fn send(&mut self, line: &str) {
        writeln!(self.writer, "{line}").unwrap();
    }

    fn next_event(&mut self) -> expertweave::util::json::Json {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).unwrap();
        assert!(n > 0, "server closed the connection unexpectedly");
        expertweave::util::json::Json::parse(line.trim()).unwrap()
    }

    /// Read events until one matches `event` for request `id`.
    fn wait_for(&mut self, id: &str, event: &str) -> expertweave::util::json::Json {
        for _ in 0..10_000 {
            let ev = self.next_event();
            if ev.get("id").and_then(|i| i.as_str()) == Some(id)
                && ev.get("event").and_then(|e| e.as_str()) == Some(event)
            {
                return ev;
            }
        }
        panic!("no {event:?} event for {id:?}");
    }
}

#[test]
fn ndjson_tcp_serve_stream_cancel_drain() {
    let server = NdjsonServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let serving = std::thread::spawn(move || {
        // the engine lives entirely on the serving thread (same rule as
        // fleet replicas: engines never cross threads)
        let (mut engine, names) = sim_engine(EngineOptions::default());
        server.run(&mut engine).unwrap();
        let report = engine.report();
        (report, names)
    });

    // discover the adapter names the same way the serving thread does
    let adapter = {
        let cfg = ModelConfig::sim_default();
        synth_fleet_adapters(&cfg, 2, 42)[0].name.clone()
    };

    let mut c = Client::connect(addr);

    // 1) submit and stream to completion
    c.send(&format!(
        r#"{{"id":"r1","adapter":"{adapter}","prompt":[1,2,3,4],"max_new_tokens":3}}"#
    ));
    let first = c.wait_for("r1", "first");
    assert!(first.get("token").and_then(|t| t.as_i64()).is_some());
    let done = c.wait_for("r1", "done");
    let tokens = done.get("tokens").and_then(|t| t.as_arr()).unwrap();
    assert_eq!(tokens.len(), 3);
    assert!(done.get("ttft_ms").and_then(|v| v.as_f64()).unwrap() >= 0.0);

    // 2) cancel one mid-stream
    c.send(r#"{"id":"r2","prompt":[5,6,7],"max_new_tokens":4000}"#);
    let _ = c.wait_for("r2", "first");
    c.send(r#"{"op":"cancel","id":"r2"}"#);
    let aborted = c.wait_for("r2", "aborted");
    assert_eq!(
        aborted.get("reason").and_then(|r| r.as_str()),
        Some("cancelled")
    );

    // 3) typed error for an unknown adapter
    c.send(r#"{"id":"r3","adapter":"ghost","prompt":[1],"max_new_tokens":1}"#);
    let err = c.wait_for("r3", "error");
    assert_eq!(
        err.get("code").and_then(|c| c.as_str()),
        Some("unknown_adapter")
    );

    // 4) a second connection is served concurrently
    let mut c2 = Client::connect(addr);
    c2.send(r#"{"id":"x","prompt":[9,8],"max_new_tokens":2}"#);
    let done2 = c2.wait_for("x", "done");
    assert_eq!(
        done2.get("tokens").and_then(|t| t.as_arr()).unwrap().len(),
        2
    );

    // 5) graceful drain: ack on every connection, then server exit
    c.send(r#"{"op":"drain"}"#);
    loop {
        let ev = c.next_event();
        if ev.get("event").and_then(|e| e.as_str()) == Some("drained") {
            break;
        }
    }
    drop(c);
    drop(c2);
    let (report, _names) = serving.join().unwrap();
    // r1 + x completed; r2 cancelled; r3 rejected
    assert_eq!(report.requests, 2);
    assert_eq!(report.aborted, 1);
    assert_eq!(report.rejected, 1);
}

/// Pump an [`NdjsonClient`] until the handle's stream terminates, and
/// return the completion (panics on an abort/error frame — a server-side
/// parse rejection would surface here).
fn wire_completion(client: &mut NdjsonClient, h: &RequestHandle) -> Completion {
    let mut evs = Vec::new();
    for _ in 0..30_000 {
        let _ = client.pump().unwrap();
        evs.extend(h.drain_events());
        if let Some(ev) = evs.iter().find(|e| e.is_terminal()) {
            match ev {
                TokenEvent::Done { completion, .. } => return completion.clone(),
                other => panic!("stream ended without Done: {other:?}"),
            }
        }
    }
    panic!("no terminal event ({} events so far)", evs.len());
}

/// Seeds in the upper half of the u64 range (>= 2^63) round-trip the
/// wire losslessly: the client ships them as decimal strings (an i64
/// `Int` wire form would wrap negative and be rejected at parse — the
/// regression this test pins), so a seeded sampled request submitted
/// over TCP reproduces the in-process token stream exactly, twice. The
/// third request covers the `-inf` logit-bias wire form: the finite
/// ±1e39 sentinel the client emits narrows back to ±inf server-side and
/// the banned token never appears.
#[test]
fn ndjson_big_seed_round_trips_and_inf_bias_crosses_the_wire() {
    const BIG_SEED: u64 = u64::MAX - 12345; // i64 form would be negative

    fn sampled(seed: u64) -> ServeRequest {
        ServeRequest {
            adapter: None,
            prompt: vec![1, 2, 3, 4],
            max_new_tokens: 6,
            sampling: SamplingParams::top_p(0.9, 0.8).with_seed(seed),
            deadline: None,
            trace: None,
        }
    }

    // in-process reference stream from an identically constructed engine
    let reference = {
        let (mut e, _) = sim_engine(EngineOptions::default());
        let h = e.submit_request(sampled(BIG_SEED)).unwrap();
        while ServingBackend::pump(&mut e).unwrap() {}
        let done = h
            .drain_events()
            .into_iter()
            .find_map(|ev| match ev {
                TokenEvent::Done { completion, .. } => Some(completion),
                _ => None,
            })
            .expect("reference request must complete");
        done.output
    };
    assert_eq!(reference.len(), 6);

    let server = NdjsonServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let serving = std::thread::spawn(move || {
        let (mut engine, _names) = sim_engine(EngineOptions::default());
        server.run(&mut engine).unwrap();
    });

    let mut client = NdjsonClient::connect(&addr.to_string()).unwrap();

    // 1) + 2) the same big-seed request, twice: both must equal the
    // in-process reference byte for byte (the old Int wire form lost
    // ~half of loadgen's full-range seeds to a protocol error here)
    for round in 0..2 {
        let h = client.submit(sampled(BIG_SEED)).unwrap();
        let done = wire_completion(&mut client, &h);
        assert_eq!(
            done.output, reference,
            "wire stream diverged from the in-process reference (round {round})"
        );
    }

    // 3) ban the reference's first sampled token with a -inf bias: the
    // stream must still complete and never contain the banned token
    let banned = reference[0];
    let mut req = sampled(BIG_SEED);
    req.sampling.logit_bias = vec![(banned, f32::NEG_INFINITY)];
    let h = client.submit(req).unwrap();
    let done = wire_completion(&mut client, &h);
    assert_eq!(done.output.len(), 6);
    assert!(
        !done.output.contains(&banned),
        "-inf-biased token {banned} sampled anyway: {:?}",
        done.output
    );

    ServingBackend::drain(&mut client).unwrap();
    drop(client);
    serving.join().unwrap();
}
