// De-risk probe: check whether execute outputs are untupled by PJRT.
// Requires a real xla_extension build plus /tmp/probe4.hlo.txt (emitted
// by the python AOT pipeline); skips itself everywhere else — the
// vendored `xla` stub cannot execute, and CI has no probe artifact.
#[test]
fn untuple_check() {
    if !std::path::Path::new("/tmp/probe4.hlo.txt").exists() {
        eprintln!("SKIP: /tmp/probe4.hlo.txt missing (python AOT probe not run)");
        return;
    }
    let client = xla::PjRtClient::cpu().unwrap();
    let proto = xla::HloModuleProto::from_text_file("/tmp/probe4.hlo.txt").unwrap();
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = match client.compile(&comp) {
        Ok(exe) => exe,
        // only the vendored stub's canned error is a skip; a compile
        // failure from a real xla_extension is exactly the regression
        // this probe exists to catch
        Err(e) if e.to_string().contains("xla stub") => {
            eprintln!("SKIP: {e}");
            return;
        }
        Err(e) => panic!("PJRT compile failed: {e}"),
    };
    // build literals per probe4 signature: kv[32,8]f32, xs[16,16]f32, ws[12,16,8]f32,
    // offs[13]i32, ids[8,2]i32, aid[8]i32, emap[3,6]i32
    let kv = xla::Literal::vec1(&vec![0f32; 32*8]).reshape(&[32,8]).unwrap();
    let xs = xla::Literal::vec1(&vec![1f32; 16*16]).reshape(&[16,16]).unwrap();
    let ws = xla::Literal::vec1(&vec![1f32; 12*16*8]).reshape(&[12,16,8]).unwrap();
    let offs = xla::Literal::vec1(&{let mut v=vec![0i32;13]; for i in 0..13 {v[i]= (i as i32).min(16)} ; for i in 0..13 { v[i] = std::cmp::min(16, (i*2) as i32)} v}).reshape(&[13]).unwrap();
    let ids = xla::Literal::vec1(&vec![0i32; 16]).reshape(&[8,2]).unwrap();
    let aid = xla::Literal::vec1(&vec![-1i32; 8]).reshape(&[8]).unwrap();
    let emap = xla::Literal::vec1(&vec![0i32; 18]).reshape(&[3,6]).unwrap();
    let out = exe.execute::<xla::Literal>(&[kv, xs, ws, offs, ids, aid, emap]).unwrap();
    println!("replicas={} outputs_per_replica={}", out.len(), out[0].len());
    for (i, b) in out[0].iter().enumerate() {
        let shape = b.on_device_shape().unwrap();
        println!("out[{i}]: {shape:?}");
    }
}
