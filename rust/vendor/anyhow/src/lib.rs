//! Vendored, dependency-free subset of the `anyhow` API.
//!
//! The build environment for this repository is offline (no crates.io
//! access), so the crate ships the thin slice of `anyhow` it actually
//! uses: [`Error`], [`Result`], the [`Context`] extension trait and the
//! `anyhow!` / `bail!` / `ensure!` macros. Error values are flattened to
//! a message chain (outermost first); `{:#}` renders the full chain
//! separated by `": "`, mirroring anyhow's alternate formatting.

use std::fmt;

/// A flattened error: an ordered chain of messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

/// `Result<T, anyhow::Error>` with the same default-parameter shape as
/// the real crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Prepend a higher-level context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                if self.chain.len() > 2 {
                    write!(f, "\n    {i}: {c}")?;
                } else {
                    write!(f, "\n    {c}")?;
                }
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(cause) = source {
            chain.push(cause.to_string());
            source = cause.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

// One blanket impl over `Into<Error>` covers both foreign errors (via
// the `From` above) and `anyhow::Error` itself (reflexive `Into`).
impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn context_chains_and_formats() {
        let r: Result<()> = Err(io_err()).context("loading config");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: missing file");
        assert_eq!(e.root_cause(), "missing file");
    }

    #[test]
    fn option_context_and_macros() {
        let v: Option<u32> = None;
        let e = v.context("no value").unwrap_err();
        assert_eq!(format!("{e}"), "no value");
        fn fails(n: usize) -> Result<()> {
            ensure!(n < 3, "n too large: {n}");
            bail!("always fails ({n})");
        }
        assert_eq!(format!("{:#}", fails(9).unwrap_err()), "n too large: 9");
        assert_eq!(format!("{:#}", fails(1).unwrap_err()), "always fails (1)");
    }

    #[test]
    fn with_context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.with_context(|| format!("outer {}", 7)).unwrap_err();
        assert_eq!(format!("{e:#}"), "outer 7: inner");
        assert_eq!(e.chain().count(), 2);
    }
}
