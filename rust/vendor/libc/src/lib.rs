//! Vendored minimal subset of the `libc` crate (Linux).
//!
//! The offline build has no crates.io access; the vmm layer only needs
//! the mmap/memfd surface below, so that is all this shim declares.
//! Values are the Linux generic ones (identical on x86_64 and aarch64
//! for every constant here).

#![allow(non_camel_case_types)]

pub type c_char = std::ffi::c_char;
pub type c_void = std::ffi::c_void;
pub type c_int = i32;
pub type c_uint = u32;
pub type c_long = i64;
pub type size_t = usize;
pub type off_t = i64;

pub const PROT_NONE: c_int = 0;
pub const PROT_READ: c_int = 1;
pub const PROT_WRITE: c_int = 2;

pub const MAP_SHARED: c_int = 0x0001;
pub const MAP_PRIVATE: c_int = 0x0002;
pub const MAP_FIXED: c_int = 0x0010;
pub const MAP_ANONYMOUS: c_int = 0x0020;
pub const MAP_NORESERVE: c_int = 0x4000;

pub const MAP_FAILED: *mut c_void = !0 as *mut c_void;

pub const MFD_CLOEXEC: c_uint = 0x0001;

pub const _SC_PAGESIZE: c_int = 30;

extern "C" {
    pub fn mmap(
        addr: *mut c_void,
        len: size_t,
        prot: c_int,
        flags: c_int,
        fd: c_int,
        offset: off_t,
    ) -> *mut c_void;
    pub fn munmap(addr: *mut c_void, len: size_t) -> c_int;
    pub fn memfd_create(name: *const c_char, flags: c_uint) -> c_int;
    pub fn ftruncate(fd: c_int, length: off_t) -> c_int;
    pub fn sysconf(name: c_int) -> c_long;
}

#[cfg(test)]
mod tests {
    #[test]
    fn sysconf_pagesize_is_sane() {
        let ps = unsafe { super::sysconf(super::_SC_PAGESIZE) };
        assert!(ps >= 4096, "page size {ps}");
        assert_eq!(ps & (ps - 1), 0, "page size must be a power of two");
    }
}
