//! Stub of the `xla` (xla_extension) PJRT bindings.
//!
//! The offline build environment has no `xla_extension` shared library,
//! so this crate provides the exact API surface `expertweave::runtime`
//! consumes, compiled everywhere. Host-side pieces (literals, buffers)
//! are functional; anything that would need a real XLA compiler or PJRT
//! device — [`PjRtClient::compile`], executable execution — returns a
//! descriptive error. Callers are expected to skip PJRT paths when the
//! AOT artifacts are absent (which is always true when this stub is in
//! use); the in-repo simulation backend (`expertweave::runtime::sim`)
//! covers serving experiments instead.

use std::fmt;

const STUB_MSG: &str =
    "xla stub: PJRT runtime unavailable in this build (no xla_extension); \
     use the sim backend or link the real xla crate";

/// Error type mirroring `xla::Error` closely enough for `?` + context.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub_err<T>() -> Result<T> {
    Err(Error(STUB_MSG.to_string()))
}

/// Element types a [`Literal`] / device buffer can hold.
#[derive(Debug, Clone)]
enum Elements {
    F32(Vec<f32>),
    F64(Vec<f64>),
    I32(Vec<i32>),
    I64(Vec<i64>),
    U32(Vec<u32>),
}

impl Elements {
    fn len(&self) -> usize {
        match self {
            Elements::F32(v) => v.len(),
            Elements::F64(v) => v.len(),
            Elements::I32(v) => v.len(),
            Elements::I64(v) => v.len(),
            Elements::U32(v) => v.len(),
        }
    }
}

/// Sealed-by-convention marker for supported element types.
pub trait ArrayElement: Sized {
    fn wrap(data: Vec<Self>) -> Elements2;
    fn unwrap(e: &Elements2) -> Option<Vec<Self>>;
}

/// Public alias so `ArrayElement` signatures don't leak the private enum.
pub struct Elements2(Elements);

macro_rules! impl_element {
    ($t:ty, $variant:ident) => {
        impl ArrayElement for $t {
            fn wrap(data: Vec<Self>) -> Elements2 {
                Elements2(Elements::$variant(data))
            }
            fn unwrap(e: &Elements2) -> Option<Vec<Self>> {
                match &e.0 {
                    Elements::$variant(v) => Some(v.clone()),
                    _ => None,
                }
            }
        }
    };
}

impl_element!(f32, F32);
impl_element!(f64, F64);
impl_element!(i32, I32);
impl_element!(i64, I64);
impl_element!(u32, U32);

/// Array shape (dims only; the stub tracks no layouts).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Shape {
    pub dims: Vec<i64>,
}

/// Host literal: typed elements + shape. Fully functional in the stub.
pub struct Literal {
    data: Elements2,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: ArrayElement + Clone>(data: &[T]) -> Literal {
        let dims = vec![data.len() as i64];
        Literal { data: T::wrap(data.to_vec()), dims }
    }

    /// Reinterpret with new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let count: i64 = dims.iter().product();
        if count != self.data.0.len() as i64 {
            return Err(Error(format!(
                "reshape: {} elements into dims {dims:?}",
                self.data.0.len()
            )));
        }
        Ok(Literal { data: Elements2(self.data.0.clone()), dims: dims.to_vec() })
    }

    /// Copy out as a typed vector.
    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| Error("literal element type mismatch".to_string()))
    }

    /// Destructure a 2-tuple literal. The stub never produces tuples
    /// (execution is unavailable), so this always errors.
    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        stub_err()
    }

    pub fn shape(&self) -> Result<Shape> {
        Ok(Shape { dims: self.dims.clone() })
    }
}

/// Parsed HLO module text. The stub only records the source path.
pub struct HloModuleProto {
    pub path: String,
}

impl HloModuleProto {
    /// Reads the file (so missing-artifact errors surface naturally) but
    /// performs no parsing.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        std::fs::read(path).map_err(|e| Error(format!("read {path}: {e}")))?;
        Ok(HloModuleProto { path: path.to_string() })
    }
}

/// An XLA computation handle.
pub struct XlaComputation {
    _path: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _path: proto.path.clone() }
    }
}

/// Device buffer. In the stub it is a host literal in disguise.
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(Literal {
            data: Elements2(self.lit.data.0.clone()),
            dims: self.lit.dims.clone(),
        })
    }

    pub fn on_device_shape(&self) -> Result<Shape> {
        self.lit.shape()
    }
}

/// Compiled executable handle. Unobtainable from the stub client
/// (compilation errors out), so execution methods are unreachable; they
/// still exist so dependent code type-checks.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub_err()
    }

    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub_err()
    }
}

/// PJRT client. Construction succeeds (cheap); compilation reports the
/// stub condition.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub_err()
    }

    pub fn buffer_from_host_buffer<T: ArrayElement + Clone>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let count: usize = dims.iter().product();
        if count != data.len() {
            return Err(Error(format!(
                "buffer_from_host_buffer: {} elements into dims {dims:?}",
                data.len()
            )));
        }
        Ok(PjRtBuffer {
            lit: Literal {
                data: T::wrap(data.to_vec()),
                dims: dims.iter().map(|&d| d as i64).collect(),
            },
        })
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        lit: &Literal,
    ) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer {
            lit: Literal {
                data: Elements2(lit.data.0.clone()),
                dims: lit.dims.clone(),
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.shape().unwrap().dims, vec![2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn compile_reports_stub() {
        let client = PjRtClient::cpu().unwrap();
        let proto = HloModuleProto { path: "unused".into() };
        let comp = XlaComputation::from_proto(&proto);
        let err = client.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("stub"));
    }
}
