#!/usr/bin/env sh
# Snapshot bench results into the repo's committed perf trajectory.
#
# Benches write BENCH_*.json under target/bench_results/ (gitignored,
# per-run). This script copies them into bench/ — the tracked baseline
# directory — and writes bench/SUMMARY.json, a schema-stable index of
# what was captured and from which revision, so successive commits of
# bench/ form a perf trajectory reviewable in git history.
#
# Usage: scripts/bench_snapshot.sh [src-dir] [dst-dir]
#   src-dir  defaults to target/bench_results
#   dst-dir  defaults to bench
#
# CI runs this after the hot-path bench and uploads bench/ as an
# artifact; committing the refreshed bench/ is a deliberate, human
# act (baselines should move when performance moved, not on noise).

set -eu

SRC="${1:-target/bench_results}"
DST="${2:-bench}"

if [ ! -d "$SRC" ]; then
    echo "bench_snapshot: no $SRC directory — run a bench first" >&2
    echo "  e.g. cargo bench --bench fig11_hotpath -- --reps 2" >&2
    exit 1
fi

found=0
for f in "$SRC"/BENCH_*.json; do
    [ -e "$f" ] || break
    found=1
done
if [ "$found" -eq 0 ]; then
    echo "bench_snapshot: no BENCH_*.json under $SRC" >&2
    exit 1
fi

mkdir -p "$DST"

GIT_SHA=$(git rev-parse --short HEAD 2>/dev/null || echo unknown)
DATE=$(date -u +%Y-%m-%dT%H:%M:%SZ)

# SUMMARY.json schema (version 1, stable: additive changes only):
# { "schema": 1, "git": "<sha>", "captured_at": "<iso8601>",
#   "benches": [ { "file": "BENCH_x.json", "bench": "<bench field>" } ] }
summary="$DST/SUMMARY.json"
{
    printf '{"schema":1,"git":"%s","captured_at":"%s","benches":[' \
        "$GIT_SHA" "$DATE"
    sep=""
    for f in "$SRC"/BENCH_*.json; do
        base=$(basename "$f")
        cp "$f" "$DST/$base"
        # the "bench" field names the harness that emitted the file
        bench=$(sed -n 's/.*"bench":"\([^"]*\)".*/\1/p' "$f" | head -n 1)
        printf '%s{"file":"%s","bench":"%s"}' "$sep" "$base" "${bench:-unknown}"
        sep=","
        echo "bench_snapshot: $base -> $DST/$base" >&2
    done
    printf ']}\n'
} > "$summary"

echo "bench_snapshot: wrote $summary (git $GIT_SHA)" >&2
